"""Quickstart: build a document-retrieval index over a repetitive
collection and run the paper's three query types plus TF-IDF.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.suffix import concat_documents
from repro.data.collections import SyntheticSpec, generate
from repro.serve.retrieval import RetrievalService
from repro.core.suffix import encode_pattern


def main():
    # a versioned collection: 20 near-identical revisions of 5 base docs
    coll = generate(
        SyntheticSpec("version", n_base=5, n_variants=20, base_len=300,
                      mutation_rate=0.005, sigma="acgt")
    )
    print(f"collection: n={coll.n} symbols, d={coll.d} documents")

    svc = RetrievalService.build(coll, block_size=32, beta=8.0)
    report = svc.space_report()
    print("\nindex space (bits/char):")
    for k, v in report.items():
        print(f"  {k:22s} {v if isinstance(v, int) else round(v, 3)}")

    # take a few patterns straight out of the text
    text = coll.text
    pats = []
    rng = np.random.default_rng(0)
    while len(pats) < 4:
        p = int(rng.integers(0, coll.n - 6))
        sub = text[p : p + 5]
        if (sub > 0).all():
            pats.append(np.asarray(sub - 1, dtype=np.int32) + 1)

    print("\ndocument counting (df):", svc.count(pats).tolist())
    print("counting cross-check  :", svc.count_ilcp(pats).tolist())

    listing = svc.list_docs(pats, max_df=coll.d + 1)
    print("\ndocument listing:")
    for i, docs in enumerate(listing):
        print(f"  pattern {i}: {len(docs)} docs -> {docs[:10]}{'...' if len(docs) > 10 else ''}")

    print("\ntop-5 by term frequency:")
    for i, hits in enumerate(svc.topk(pats, k=5)):
        print(f"  pattern {i}: {hits}")

    print("\nranked-OR tf-idf (2-term queries):")
    out = svc.tfidf([[pats[0], pats[1]], [pats[2], pats[3]]], k=5)
    for i, hits in enumerate(out):
        print(f"  query {i}: {[(d, round(s, 2)) for d, s in hits]}")


if __name__ == "__main__":
    main()
