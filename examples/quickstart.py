"""Quickstart: build a document-retrieval index over a repetitive
collection and run the paper's three query types plus TF-IDF — all served
by the batched engine (one compiled program per query type and shape
bucket; see repro.serve.retrieval).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data.collections import SyntheticSpec, generate
from repro.serve.retrieval import RetrievalService


def main():
    # a versioned collection: 20 near-identical revisions of 5 base docs
    coll = generate(
        SyntheticSpec("version", n_base=5, n_variants=20, base_len=300,
                      mutation_rate=0.005, sigma="acgt")
    )
    print(f"collection: n={coll.n} symbols, d={coll.d} documents")

    svc = RetrievalService.build(coll, block_size=32, beta=8.0)
    report = svc.space_report()
    print("\nindex space (bits/char):")
    for k, v in report.items():
        print(f"  {k:22s} {v if isinstance(v, int) else round(v, 3)}")

    # take a few patterns straight out of the text
    text = coll.text
    pats = []
    rng = np.random.default_rng(0)
    while len(pats) < 4:
        p = int(rng.integers(0, coll.n - 6))
        sub = text[p : p + 5]
        if (sub > 0).all():
            pats.append(np.asarray(sub - 1, dtype=np.int32) + 1)

    # one fused program computes ranges, df, occ AND the engine dispatch
    plan = svc.plan(pats)
    print("\nquery plan (device-computed dispatch):")
    print("  df     :", plan["df"].tolist())
    print("  occ    :", plan["occ"].tolist())
    print("  engine :", plan["engine"].tolist(), "(1=brute, 3=pdl)")
    print("counting cross-check  :", svc.count_ilcp(pats).tolist())

    # batched listing: docs come back as a padded array (ascending ids,
    # -1 sentinels) — the list view is a host convenience on top of it
    docs, counts = svc.list_docs_arrays(pats, max_df=coll.d + 1)
    print("\ndocument listing (batched):")
    for i in range(len(pats)):
        row = docs[i, : counts[i]].tolist()
        print(f"  pattern {i}: {counts[i]} docs -> {row[:10]}{'...' if counts[i] > 10 else ''}")

    print("\ntop-5 by term frequency:")
    for i, hits in enumerate(svc.topk(pats, k=5)):
        print(f"  pattern {i}: {hits}")

    print("\nranked-OR tf-idf (2-term queries):")
    out = svc.tfidf([[pats[0], pats[1]], [pats[2], pats[3]]], k=5)
    for i, hits in enumerate(out):
        print(f"  query {i}: {[(d, round(s, 2)) for d, s in hits]}")

    # every batched endpoint is bit-identical to the per-query reference
    assert svc.list_docs(pats) == svc.list_docs(pats, engine="reference")
    print(f"\nreference parity OK; compiles per endpoint: "
          f"{dict(svc.compile_counts)}")


if __name__ == "__main__":
    main()
