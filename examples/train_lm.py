"""End-to-end training driver: train a ~100M-parameter llama-style model
for a few hundred steps on this host, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--params 100]

The model is a width-reduced smollm-family config sized to ~``--params``
million parameters; data comes from the synthetic corpus pipeline.  The
loop is the production one (repro.train.loop): resume-from-checkpoint,
periodic atomic saves, straggler accounting.
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.data.pipelines import Prefetcher, lm_batches
from repro.models.transformer import LMConfig, forward_train, init_params
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig

import jax


def config_for_params(target_m: float) -> LMConfig:
    """Scale width to hit roughly target_m million params (depth fixed)."""
    vocab, layers = 32000, 12
    d = 256
    while True:
        cfg = LMConfig(
            name=f"lm-{target_m}m", n_layers=layers, d_model=d,
            n_heads=max(4, d // 64), n_kv_heads=max(2, d // 128),
            d_ff=int(d * 8 / 3) // 64 * 64, vocab=vocab, tie_embeddings=True,
            param_dtype=jnp.float32, act_dtype=jnp.float32,
        )
        if cfg.param_count() >= target_m * 1e6 or d > 4096:
            return cfg
        d += 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", type=float, default=100, help="millions")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = config_for_params(args.params)
    print(f"model: {cfg.name}  d_model={cfg.d_model}  params={cfg.param_count()/1e6:.0f}M")

    batches = Prefetcher(lm_batches(cfg.vocab, args.batch, args.seq))
    batch_cache = {}

    def batch_fn(step):
        b = next(batches)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def loss_fn(params, batch):
        return forward_train(cfg, params, batch["tokens"], batch["labels"])

    res = train(
        loss_fn,
        lambda: init_params(cfg, jax.random.PRNGKey(0)),
        batch_fn,
        n_steps=args.steps,
        ckpt_dir=args.ckpt,
        ckpt_every=50,
        opt_cfg=AdamWConfig(lr=3e-4),
    )
    w = 20
    print(f"loss: first{w}={np.mean(res.losses[:w]):.3f} "
          f"last{w}={np.mean(res.losses[-w:]):.3f} "
          f"(restarts={res.restarts}, stragglers={res.straggler_steps})")
    batches.close()


if __name__ == "__main__":
    main()
