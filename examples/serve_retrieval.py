"""Retrieval serving with batched requests: the paper's indexes behind the
planned, masked, jit-compiled pipeline.

Every batch below executes as ONE compiled program per (endpoint, shape
bucket): the planner computes ranges + df + the paper's occ/df engine
dispatch on device, the masked executors run every engine over its
sub-batch, and the shape-bucketing cache bounds recompilation (batch sizes
round up to powers of two).  The report at the end shows how few XLA
compiles served the whole workload.

    PYTHONPATH=src python examples/serve_retrieval.py [--requests 200]
"""

import argparse
import time

import numpy as np

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.serve.retrieval import RetrievalService
from repro.serve.planner import ENGINE_BRUTE, ENGINE_PDL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    coll = generate(
        SyntheticSpec("version", n_base=8, n_variants=16, base_len=400,
                      mutation_rate=0.01)
    )
    print(f"corpus: n={coll.n}, d={coll.d}")
    t0 = time.time()
    svc = RetrievalService.build(coll, block_size=32, beta=8.0)
    print(f"index build: {time.time() - t0:.1f}s "
          f"(BWT runs={svc.csa.bwt_runs}, ILCP runs={svc.ilcp.nruns})")

    workload = random_substring_patterns(coll, 800, 6, 64)
    if not workload:
        raise SystemExit("no patterns extracted")

    # the planner's engine mix for this workload (device-computed dispatch)
    plan = svc.plan(workload)
    n_brute = int((plan["engine"] == ENGINE_BRUTE).sum())
    n_pdl = int((plan["engine"] == ENGINE_PDL).sum())
    print(f"planner dispatch over {len(workload)} patterns: "
          f"{n_brute} brute / {n_pdl} pdl (occ/df threshold "
          f"{svc.occ_df_threshold})")

    lat = []
    served = 0
    rng = np.random.default_rng(0)
    while served < args.requests:
        batch = [workload[i] for i in rng.integers(0, len(workload), args.batch)]
        t0 = time.perf_counter()
        dfs = svc.count(batch)
        docs, tfs = svc.topk_arrays(batch, k=args.k)   # zero-copy array layout
        lat.append(time.perf_counter() - t0)
        served += len(batch)
    lat_ms = np.asarray(lat) * 1e3
    print(f"served {served} queries in batches of {args.batch}")
    print(f"batch latency ms: p50={np.percentile(lat_ms, 50):.1f} "
          f"p99={np.percentile(lat_ms, 99):.1f} "
          f"throughput={served / lat_ms.sum() * 1e3:.0f} q/s")
    print(f"XLA compiles by endpoint (one per shape bucket): "
          f"{dict(svc.compile_counts)}")
    hits = [(int(d), int(t)) for d, t in zip(docs[0], tfs[0]) if d >= 0]
    print(f"example: df={int(dfs[0])}, top-{args.k}={hits[:3]}...")

    # parity spot-check against the per-query reference path
    sample = workload[:8]
    assert svc.topk(sample, k=args.k) == svc.topk(
        sample, k=args.k, engine="reference"
    ), "batched engine diverged from reference"
    print("parity spot-check vs engine='reference': OK")


if __name__ == "__main__":
    main()
