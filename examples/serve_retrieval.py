"""Retrieval serving with batched requests behind the resilient runtime.

Every batch executes as ONE compiled program per (endpoint, shape bucket);
the ``ServeRuntime`` in front adds per-request deadlines, retry/breaker
fault handling, and graceful degradation.  Latency is reported honestly:
the first execution of each (endpoint, bucket) pays the AOT compile and is
reported separately from the steady-state percentiles — mixing the two
(as the old version of this script did) makes p99 a compile benchmark.

    PYTHONPATH=src python examples/serve_retrieval.py [--requests 200]
        [--deadline-ms 500] [--inject executor_fail:0.1,slow_pdl]
"""

import argparse
import time

import numpy as np

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.serve import faults
from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import RuntimeConfig, ServeRuntime
from repro.serve.planner import ENGINE_BRUTE, ENGINE_PDL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="per-request deadline (see ServeRuntime)")
    ap.add_argument("--inject", default=None,
                    help="comma-separated fault specs, e.g. "
                         "'executor_fail:0.1,slow_pdl' (see repro.serve.faults)")
    args = ap.parse_args()

    coll = generate(
        SyntheticSpec("version", n_base=8, n_variants=16, base_len=400,
                      mutation_rate=0.01)
    )
    print(f"corpus: n={coll.n}, d={coll.d}")
    t0 = time.time()
    svc = RetrievalService.build(coll, block_size=32, beta=8.0)
    print(f"index build: {time.time() - t0:.1f}s "
          f"(BWT runs={svc.csa.bwt_runs}, ILCP runs={svc.ilcp.nruns}, "
          f"integrity fingerprints: {sorted(svc.fingerprints)})")

    workload = random_substring_patterns(coll, 800, 6, 64)
    if not workload:
        raise SystemExit("no patterns extracted")

    # the planner's engine mix for this workload (device-computed dispatch)
    plan = svc.plan(workload)
    n_brute = int((plan["engine"] == ENGINE_BRUTE).sum())
    n_pdl = int((plan["engine"] == ENGINE_PDL).sum())
    print(f"planner dispatch over {len(workload)} patterns: "
          f"{n_brute} brute / {n_pdl} pdl (occ/df threshold "
          f"{svc.occ_df_threshold})")

    rt = ServeRuntime(svc, RuntimeConfig(
        max_batch=args.batch, k=args.k,
        default_deadline_s=args.deadline_ms / 1e3,
    ))
    rt.warmup(kinds=("count", "topk"), batch_sizes=(args.batch,))
    # realistic warm waves settle the grow-only brute windows (each growth
    # recompiles the bucket) so the timed loop below is steady-state
    warm_rng = np.random.default_rng(1)
    for kind in ("count", "topk"):
        for _ in range(2):
            rt.serve([(kind, workload[i])
                      for i in warm_rng.integers(0, len(workload), args.batch)],
                     deadline_s=1e9)

    specs = faults.parse_fault_specs(args.inject) if args.inject else []
    served = 0
    lat = []
    rng = np.random.default_rng(0)
    with faults.inject(*specs):
        while served < args.requests:
            batch = [workload[i]
                     for i in rng.integers(0, len(workload), args.batch)]
            t0 = time.perf_counter()
            for p in batch:
                rt.submit("count", p)
                rt.submit("topk", p)
            answers = rt.run_until_idle()
            lat.append(time.perf_counter() - t0)
            served += len(batch)
    m = rt.metrics
    lat_ms = np.asarray(lat) * 1e3
    print(f"served {served} queries in batches of {args.batch}"
          + (f" with faults {args.inject}" if args.inject else ""))
    print(f"steady-state batch latency ms: p50={np.percentile(lat_ms, 50):.1f} "
          f"p99={np.percentile(lat_ms, 99):.1f} "
          f"throughput={2 * served / lat_ms.sum() * 1e3:.0f} q/s")
    print(f"compile cost per (endpoint, bucket), excluded from the above: "
          f"{m.as_dict()['compile_s']}")
    print(f"resilience: degraded_fraction={m.degraded_fraction:.3f} "
          f"deadline_miss_rate={m.deadline_miss_rate:.3f} "
          f"retries={m.retries} breaker_trips={m.breaker_trips}")
    print(f"XLA compiles by endpoint (one per shape bucket): "
          f"{dict(svc.compile_counts)}")
    sample = next(a for a in answers.values() if a.kind == "topk")
    print(f"example: top-{args.k}={sample.result[:3]}... "
          f"(degraded={sample.degraded})")

    # parity spot-check against the per-query reference path
    sample_pats = workload[:8]
    assert svc.topk(sample_pats, k=args.k) == svc.topk(
        sample_pats, k=args.k, engine="reference"
    ), "batched engine diverged from reference"
    print("parity spot-check vs engine='reference': OK")


if __name__ == "__main__":
    main()
