"""Multi-term ranked search (Section 6.5) over a versioned text corpus:
conjunctive and disjunctive tf-idf with phrase terms.

    PYTHONPATH=src python examples/tfidf_search.py
"""

import numpy as np

from repro.core.suffix import concat_documents, encode_pattern
from repro.serve.retrieval import RetrievalService


def main():
    rng = np.random.default_rng(7)
    vocab = ["fox", "dog", "cat", "bird", "quick", "lazy", "brown", "jumps"]
    docs = []
    for i in range(24):
        words = [vocab[j] for j in rng.integers(0, len(vocab), 30)]
        words += ["fox"] * (i % 5) + ["dog"] * (i % 3)
        docs.append(" ".join(words))
    coll = concat_documents(docs)
    svc = RetrievalService.build(coll, block_size=32, beta=None)

    queries = [
        (["fox"], False),
        (["fox", "dog"], False),
        (["fox", "dog"], True),
        (["quick brown"], False),     # phrase term — free on a string index
    ]
    for terms, conj in queries:
        encoded = [encode_pattern(t) for t in terms]
        out = svc.tfidf([encoded], k=5, conjunctive=conj)[0]
        kind = "AND" if conj else "OR"
        print(f"{kind:3s} {terms}: " +
              ", ".join(f"doc{d}({s:.2f})" for d, s in out))


if __name__ == "__main__":
    main()
