"""Fig 5: runs of 1-bits in Sadakane's H' bitvector on synthetic DNA
collections vs mutation rate, against the expected-case bound of
Section 5.3 ((sigma/2 + 1) * m * sqrt(d))."""

from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit
from repro.core.sada import hprime_runs_of_ones
from repro.core.suffix import build_suffix_data
from repro.data.collections import SyntheticSpec, generate


def run():
    rows = []
    m = max(2, int(128 * SCALE))       # base document length
    d = max(2, int(64 * SCALE))        # number of documents
    sigma = 4
    bound = (sigma / 2 + 1) * m * np.sqrt(d)
    for p in (0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0):
        spec = SyntheticSpec("dna", n_base=1, n_variants=d, base_len=m,
                             mutation_rate=p)
        coll = generate(spec)
        data = build_suffix_data(coll)
        runs = hprime_runs_of_ones(data)
        rows.append([p, coll.n, runs, round(runs / coll.n, 4), round(bound, 1)])
    return emit(rows, ["mutation_rate", "n", "h_runs", "runs_per_char",
                       "expected_bound_p1"])


if __name__ == "__main__":
    run()
