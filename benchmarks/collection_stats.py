"""Table 1: collection statistics — size n, RLCSA size, documents d,
average document size, pattern count, occ, df, occ/df."""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_collections, emit, patterns_for, suffix_data_for


def run():
    rows = []
    for name, coll in bench_collections().items():
        data = suffix_data_for(name)
        from repro.core.csa import build_csa

        csa = build_csa(data)
        pats, ranges = patterns_for(name)
        occs, dfs = [], []
        for lo, hi in ranges:
            occ = int(hi - lo)
            if occ == 0:
                continue
            occs.append(occ)
            dfs.append(len(set(data.da[lo:hi].tolist())))
        occ = float(np.mean(occs)) if occs else 0.0
        df = float(np.mean(dfs)) if dfs else 0.0
        rows.append(
            [
                name,
                coll.n,
                round(csa.modeled_bits_rlcsa() / 8 / 2**10, 2),  # KB
                coll.d,
                coll.n // max(coll.d, 1),
                len(pats),
                round(occ, 1),
                round(df, 1),
                round(occ / max(df, 1e-9), 2),
            ]
        )
    return emit(
        rows,
        ["collection", "n", "rlcsa_kb", "d", "avg_doc", "patterns", "occ",
         "df", "occ_per_df"],
    )


if __name__ == "__main__":
    run()
