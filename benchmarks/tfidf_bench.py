"""Table 2: ranked multi-term AND/OR queries per second (single stream and
batched — batching is the TPU analogue of the paper's query threads)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_collections, emit, patterns_for, suffix_data_for
from repro.serve.retrieval import RetrievalService


def run(name="version-p001", n_queries=16, ks=(10, 100)):
    coll = bench_collections()[name]
    svc = RetrievalService.build(coll, block_size=64)
    pats, ranges = patterns_for(name, n=32, length=5)
    pats = [p for p, (lo, hi) in zip(pats, ranges) if hi > lo][:8]
    if len(pats) < 2:
        return []
    rng = np.random.default_rng(3)
    queries = [
        [pats[i] for i in rng.choice(len(pats), 2, replace=False)]
        for _ in range(n_queries)
    ]
    rows = []
    for conj in (True, False):
        for k in ks:
            # warm
            svc.tfidf(queries[:2], k=k, conjunctive=conj)
            t0 = time.perf_counter()
            out = svc.tfidf(queries, k=k, conjunctive=conj)
            dt = time.perf_counter() - t0
            qps = n_queries / dt
            rows.append(
                ["Ranked-AND" if conj else "Ranked-OR", k, n_queries,
                 round(qps, 1), round(dt * 1e3 / n_queries, 2)]
            )
    return emit(rows, ["query_type", "k", "queries", "qps", "ms_per_query"])


if __name__ == "__main__":
    run()
