"""Backward-search microbenchmark: launch counts + planner-stage latency.

Compares the three execution paths of the planned CSA range search at
batch sizes {1, 16, 128}:

  legacy-dual-descent  csa_search_batch — vmapped per-query scan, two
                       independent wavelet descents per symbol step
                       (4 rank gathers per level)
  xla-pair-descent     csa_search_planned(use_kernel=False) — batch-first
                       scan, both SA-range boundaries on ONE descent
                       (2 rank gathers per level)
  pallas-kernel        csa_search_planned(use_kernel=True) — the fused
                       kernel: the whole batched search in ONE pallas_call
                       (interpret mode on this CPU container)

Beyond wall time, the bench *counts* the structural contract in each
variant's jaxpr: pallas_call launches per batch (1 on the kernel path,
0 elsewhere — down from the 2*m*levels rank calls a per-rank kernel would
issue) and gather equations (the pair descent halves the legacy count).
The planner stage (plan_queries: search + df + occ + dispatch) is timed on
both the kernel and fallback paths, since that is the serving-layer stage
the fusion targets.

    PYTHONPATH=src python -m benchmarks.backward_search_bench \
        [--out experiments/BENCH_backward_search.json] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_collections, emit, time_batched
from repro.core.csa import build_csa, csa_search_batch, csa_search_planned
from repro.core.sada import build_sada
from repro.core.suffix import build_suffix_data
from repro.data.collections import pad_patterns, random_substring_patterns
from repro.serve.planner import plan_queries

BATCH_SIZES = (1, 16, 128)


def count_eqns(jaxpr, name: str) -> int:
    total = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == name)
    for sub in jax.core.subjaxprs(jaxpr):
        total += count_eqns(sub, name)
    return total


def _workload(coll, B: int, rng):
    pats = random_substring_patterns(coll, max(2 * B, 16), 4, 24)
    idx = rng.integers(0, len(pats), B)
    arr, lens = pad_patterns([pats[i] for i in idx])
    return jnp.asarray(arr), jnp.asarray(lens)


def run(collections=("version-p001", "dna-p03"), batch_sizes=BATCH_SIZES,
        iters: int = 5, out: str | None = None):
    rows, results = [], []
    for name in collections:
        coll = bench_collections()[name]
        data = build_suffix_data(coll)
        csa = build_csa(data)
        sada = build_sada(data, "sparse")
        rng = np.random.default_rng(0)

        search_variants = {
            "legacy-dual-descent": jax.jit(
                lambda p, l, csa=csa: csa_search_batch(csa, p, l)
            ),
            "xla-pair-descent": jax.jit(
                lambda p, l, csa=csa: csa_search_planned(csa, p, l, use_kernel=False)
            ),
            "pallas-kernel": jax.jit(
                lambda p, l, csa=csa: csa_search_planned(csa, p, l, use_kernel=True)
            ),
        }
        plan_variants = {
            "plan-fallback": jax.jit(
                lambda p, l, csa=csa, sada=sada: plan_queries(
                    csa, sada, p, l, 4.0, -1, use_kernel=False)
            ),
            "plan-kernel": jax.jit(
                lambda p, l, csa=csa, sada=sada: plan_queries(
                    csa, sada, p, l, 4.0, -1, use_kernel=True)
            ),
        }

        for B in batch_sizes:
            pats, lens = _workload(coll, B, rng)
            for variant, fn in {**search_variants, **plan_variants}.items():
                closed = jax.make_jaxpr(fn)(pats, lens)
                launches = count_eqns(closed.jaxpr, "pallas_call")
                gathers = count_eqns(closed.jaxpr, "gather")
                med, got = time_batched(fn, pats, lens, iters=iters)
                # every variant must agree on the integers
                ref_lo, ref_hi = search_variants["legacy-dual-descent"](
                    pats, lens
                )
                if variant in search_variants:
                    lo, hi = got
                    assert np.array_equal(np.asarray(lo), np.asarray(ref_lo))
                    assert np.array_equal(np.asarray(hi), np.asarray(ref_hi))
                else:
                    assert np.array_equal(np.asarray(got.lo), np.asarray(ref_lo))
                ms = med * 1e3
                rows.append([name, variant, B, round(ms, 3), launches, gathers])
                results.append(
                    {
                        "collection": name,
                        "variant": variant,
                        "batch": B,
                        "median_ms": round(ms, 4),
                        "pallas_launches_per_batch": launches,
                        "gather_eqns": gathers,
                    }
                )
    emit(rows, ["collection", "variant", "batch", "median_ms",
                "pallas_launches", "gather_eqns"])
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"results": results, "failures": []}, f, indent=1)
        print(f"wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/BENCH_backward_search.json")
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one collection, tiny batches, 2 iters")
    args = ap.parse_args()
    if args.smoke:
        run(collections=("version-p001",), batch_sizes=(1, 16), iters=2,
            out=args.out)
    else:
        run(batch_sizes=tuple(args.batches), out=args.out)


if __name__ == "__main__":
    main()
