"""Backward-search microbenchmark: launch counts + planner-stage latency.

Compares the three execution paths of the planned CSA range search at
batch sizes {1, 16, 128}:

  legacy-dual-descent  csa_search_batch — vmapped per-query scan, two
                       independent wavelet descents per symbol step
                       (4 rank gathers per level)
  xla-pair-descent     csa_search_planned(use_kernel=False) — batch-first
                       scan, both SA-range boundaries on ONE descent
                       (2 rank gathers per level)
  pallas-kernel        csa_search_planned(use_kernel=True) — the fused
                       kernel: the whole batched search in ONE pallas_call
                       (interpret mode on this CPU container)

Beyond wall time, the bench *counts* the structural contract in each
variant's jaxpr: pallas_call launches per batch (1 on the kernel path,
0 elsewhere — down from the 2*m*levels rank calls a per-rank kernel would
issue) and gather equations (the pair descent halves the legacy count).
The planner stage (plan_queries: search + df + occ + dispatch) is timed on
both the kernel and fallback paths, since that is the serving-layer stage
the fusion targets.

A docs-mesh sweep (``--shards``, default {1, 2, 4, 8} where the host has
the devices) times the *sharded* planner program: per-shard CSA stacks,
one kernel launch per shard, psum-merged occ/df.  Every result row carries
a ``mesh_shape`` field and the per-launch resident wavelet-matrix bytes,
so the artifact shows the VMEM footprint dropping with the shard count —
the restoration mechanism for over-budget indexes.  The JSON is written to
``--out`` and mirrored at a repo-root ``BENCH_backward_search.json``.

    PYTHONPATH=src python -m benchmarks.backward_search_bench \
        [--out experiments/BENCH_backward_search.json] [--shards 1 2 4 8] \
        [--smoke]
"""

from __future__ import annotations

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    SCALE, bench_collections, emit, time_batched, write_json,
)
from repro.core.csa import build_csa, csa_search_batch, csa_search_planned
from repro.core.sada import build_sada
from repro.core.suffix import build_suffix_data, subcollection
from repro.data.collections import pad_patterns, random_substring_patterns
from repro.kernels import ops
from repro.serve.planner import plan_queries

BATCH_SIZES = (1, 16, 128)
SHARD_COUNTS = (1, 2, 4, 8)


def count_eqns(jaxpr, name: str) -> int:
    total = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == name)
    for sub in jax.core.subjaxprs(jaxpr):
        total += count_eqns(sub, name)
    return total


def _workload(coll, B: int, rng):
    pats = random_substring_patterns(coll, max(2 * B, 16), 4, 24)
    idx = rng.integers(0, len(pats), B)
    arr, lens = pad_patterns([pats[i] for i in idx])
    return jnp.asarray(arr), jnp.asarray(lens)


def _resident_bytes(csa):
    return ops.backward_search_resident_bytes(
        csa.wm.words, csa.wm.ones_prefix, csa.wm.zcount,
        csa.counts[: csa.sigma] - csa.wm.sym_starts,
    )


def _sharded_plan_variants(coll, n_shards: int):
    """Jitted sharded planner programs (kernel + fallback) over per-shard
    CSA/Sada stacks, plus the max per-launch resident bytes.

    Only the structures ``plan_queries`` touches are built — the docs-mesh
    plan program ignores the ILCP/PDL slots of each shard tuple, so the
    sweep does not pay for listing/top-k index construction."""
    from repro.dist.sharding import doc_shard_bounds, make_docs_mesh
    from repro.serve.sharded import _sharded_plan_program

    mesh = make_docs_mesh(n_shards)
    bounds = doc_shard_bounds(coll.d, n_shards)
    shard_idx, resident = [], 0
    for dlo, dhi in bounds:
        sub = subcollection(coll, dlo, dhi)
        data = build_suffix_data(sub)
        csa = build_csa(data)
        sada = build_sada(data, "sparse")
        shard_idx.append((csa, None, None, None, sada, None))
        resident = max(resident, _resident_bytes(csa))
    shard_idx = tuple(shard_idx)
    bases = tuple(b[0] for b in bounds)

    def fn(use_kernel, p, l):
        return _sharded_plan_program(
            mesh, bases, use_kernel, shard_idx, p, l,
            jnp.float32(4.0), jnp.int32(-1),
        )

    return {
        f"plan-sharded{n_shards}-fallback": jax.jit(functools.partial(fn, False)),
        f"plan-sharded{n_shards}-kernel": jax.jit(functools.partial(fn, True)),
    }, resident


def run(collections=("version-p001", "dna-p03"), batch_sizes=BATCH_SIZES,
        iters: int = 5, out: str | None = None, shard_counts=SHARD_COUNTS):
    rows, results = [], []
    feasible = [s for s in shard_counts if 1 < s <= jax.device_count()]
    skipped = [s for s in shard_counts if s > jax.device_count()]
    if skipped:
        print(f"shard sweep: skipping {skipped} "
              f"(only {jax.device_count()} devices)")
    for name in collections:
        coll = bench_collections()[name]
        data = build_suffix_data(coll)
        csa = build_csa(data)
        sada = build_sada(data, "sparse")
        rng = np.random.default_rng(0)

        search_variants = {
            "legacy-dual-descent": jax.jit(
                lambda p, l, csa=csa: csa_search_batch(csa, p, l)
            ),
            "xla-pair-descent": jax.jit(
                lambda p, l, csa=csa: csa_search_planned(csa, p, l, use_kernel=False)
            ),
            "pallas-kernel": jax.jit(
                lambda p, l, csa=csa: csa_search_planned(csa, p, l, use_kernel=True)
            ),
        }
        plan_variants = {
            "plan-fallback": jax.jit(
                lambda p, l, csa=csa, sada=sada: plan_queries(
                    csa, sada, p, l, 4.0, -1, use_kernel=False)
            ),
            "plan-kernel": jax.jit(
                lambda p, l, csa=csa, sada=sada: plan_queries(
                    csa, sada, p, l, 4.0, -1, use_kernel=True)
            ),
        }
        global_resident = _resident_bytes(csa)
        # variant -> (fn, mesh_shape, max resident bytes per kernel launch)
        meta = {v: (fn, [1], global_resident)
                for v, fn in {**search_variants, **plan_variants}.items()}
        # sharded planner sweep on the first collection only: per-shard
        # index build cost is real, and one collection shows the scaling
        if name == collections[0]:
            for n_shards in feasible:
                sharded, resident = _sharded_plan_variants(coll, n_shards)
                meta.update({v: (fn, [n_shards], resident)
                             for v, fn in sharded.items()})

        for B in batch_sizes:
            pats, lens = _workload(coll, B, rng)
            ref_lo, ref_hi = search_variants["legacy-dual-descent"](pats, lens)
            for variant, (fn, mesh_shape, resident) in meta.items():
                closed = jax.make_jaxpr(fn)(pats, lens)
                launches = count_eqns(closed.jaxpr, "pallas_call")
                gathers = count_eqns(closed.jaxpr, "gather")
                med, got = time_batched(fn, pats, lens, iters=iters)
                # every variant must agree on the integers
                if variant in search_variants:
                    lo, hi = got
                    assert np.array_equal(np.asarray(lo), np.asarray(ref_lo))
                    assert np.array_equal(np.asarray(hi), np.asarray(ref_hi))
                elif variant in plan_variants:
                    assert np.array_equal(np.asarray(got.lo), np.asarray(ref_lo))
                else:
                    # sharded plan: shard-local occ sums psum to global occ
                    occ = np.asarray(got[3])
                    assert np.array_equal(
                        occ, np.asarray(ref_hi) - np.asarray(ref_lo)
                    )
                ms = med * 1e3
                rows.append([name, variant, B, mesh_shape[0],
                             round(ms, 3), launches, gathers])
                results.append(
                    {
                        "collection": name,
                        "variant": variant,
                        "batch": B,
                        "mesh_shape": mesh_shape,
                        "scale": SCALE,
                        "median_ms": round(ms, 4),
                        "pallas_launches_per_batch": launches,
                        "gather_eqns": gathers,
                        "max_resident_bytes_per_launch": int(resident),
                        "vmem_budget_bytes": int(ops.BACKWARD_SEARCH_VMEM_BUDGET),
                    }
                )
    emit(rows, ["collection", "variant", "batch", "shards", "median_ms",
                "pallas_launches", "gather_eqns"])
    payload = {
        "results": results,
        "device_count": jax.device_count(),
        "failures": [],
    }
    write_json(out, payload, "BENCH_backward_search.json")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/BENCH_backward_search.json")
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--shards", type=int, nargs="*", default=list(SHARD_COUNTS),
                    help="docs-mesh shard counts for the sharded planner "
                         "sweep (1 = unsharded; counts past the device "
                         "count are skipped)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one collection, tiny batches, 2 iters")
    args = ap.parse_args()
    if args.smoke:
        run(collections=("version-p001",), batch_sizes=(1, 16), iters=2,
            out=args.out, shard_counts=tuple(args.shards))
    else:
        run(batch_sizes=tuple(args.batches), out=args.out,
            shard_counts=tuple(args.shards))


if __name__ == "__main__":
    main()
