"""Shared benchmark plumbing: collection cache, timers, CSV emission.

All document-retrieval benchmarks follow the paper's protocol (Section
6.2.1): query timing starts from precomputed lexicographic ranges [lo, hi)
(range-finding time is reported separately), space is reported in bits per
character using the modeled compressed sizes, and each (structure,
collection) pair emits one CSV row.

Scale note: this container is a CPU machine; collections are scaled down
from the paper's 100 MB-1 GB to ~100 KB-1 MB (the ``SCALE`` env var adjusts)
— the *relative* space/time trade-offs the paper studies are preserved, and
the repetitiveness parameters (d, mutation rates) match Section 6.1.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CACHE: dict = {}


def bench_collections():
    from repro.data.collections import generate, paperlike_collections

    if "colls" not in _CACHE:
        specs = paperlike_collections(scale=SCALE)
        _CACHE["colls"] = {name: generate(spec) for name, spec in specs.items()}
    return _CACHE["colls"]


def suffix_data_for(name: str):
    from repro.core.suffix import build_suffix_data

    key = f"sd:{name}"
    if key not in _CACHE:
        _CACHE[key] = build_suffix_data(bench_collections()[name])
    return _CACHE[key]


def patterns_for(name: str, n: int = 64, length: int = 7):
    from repro.core.suffix import sa_range_for_pattern
    from repro.data.collections import random_substring_patterns

    key = f"pat:{name}:{n}:{length}"
    if key not in _CACHE:
        coll = bench_collections()[name]
        pats = random_substring_patterns(coll, 4 * n, length, n)
        data = suffix_data_for(name)
        ranges = np.asarray(
            [sa_range_for_pattern(data, p) for p in pats], dtype=np.int32
        ).reshape(-1, 2)
        _CACHE[key] = (pats, ranges)
    return _CACHE[key]


def time_batched(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of a jitted batched call, excluding compilation."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def emit(rows, header):
    print(",".join(header))
    for row in rows:
        print(",".join(str(x) for x in row))
    print()
    return rows


def write_json(out, payload: dict, root_name: str):
    """Write the bench artifact to ``out`` and mirror it at the repo root
    (``root_name``) so the latest numbers sit next to ROADMAP.md without
    digging through experiments/."""
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {out}")
    root_path = os.path.join(REPO_ROOT, root_name)
    if os.path.abspath(out or "") != root_path:
        with open(root_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {root_path}")
