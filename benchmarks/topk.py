"""Fig 9: single-term top-k retrieval — Brute-L, Brute-D, PDL-b+F (all
internal nodes) and PDL-b-beta, for k in {10, 100}."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_collections, emit, patterns_for, suffix_data_for, time_batched
from repro.core.csa import build_csa
from repro.core.listing import brute_list_csa, brute_list_da, brute_topk
from repro.core.pdl import build_pdl, pdl_topk


def run(collections=("dna-p001", "version-p001", "random"), ks=(10, 100)):
    rows = []
    for name in collections:
        coll = bench_collections()[name]
        data = suffix_data_for(name)
        csa = build_csa(data)
        da = jnp.asarray(data.da)
        pdl_f = build_pdl(data, block_size=64, beta=None, mode="topk")
        pdl_b = build_pdl(data, block_size=64, beta=4.0, mode="topk")
        pats, ranges = patterns_for(name)
        nz = ranges[:, 1] > ranges[:, 0]
        ranges = ranges[nz]
        if not len(ranges):
            continue
        lo = jnp.asarray(ranges[:, 0])
        hi = jnp.asarray(ranges[:, 1])
        max_occ = min(int((ranges[:, 1] - ranges[:, 0]).max()), 8192)
        n = coll.n
        for k in ks:
            kk = min(k, coll.d)

            def brute_l(a, b, csa=csa, max_occ=max_occ, kk=kk):
                d_, c_, f_ = brute_list_csa(csa, a, b, max_occ)
                return brute_topk(d_, c_, f_, kk)

            def brute_d(a, b, da=da, max_occ=max_occ, kk=kk):
                d_, c_, f_ = brute_list_da(da, a, b, max_occ)
                return brute_topk(d_, c_, f_, kk)

            engines = {
                "Brute-L": (jax.jit(jax.vmap(brute_l)), 0),
                "Brute-D": (jax.jit(jax.vmap(brute_d)), n * 16),
                "PDL-64+F": (
                    jax.jit(jax.vmap(lambda a, b, pdl_f=pdl_f, csa=csa, kk=kk: pdl_topk(pdl_f, csa, a, b, kk, max_buf=2048))),
                    pdl_f.modeled_bits(),
                ),
                "PDL-64-4": (
                    jax.jit(jax.vmap(lambda a, b, pdl_b=pdl_b, csa=csa, kk=kk: pdl_topk(pdl_b, csa, a, b, kk, max_buf=2048))),
                    pdl_b.modeled_bits(),
                ),
            }
            ref = None
            for ename, (fn, bits) in engines.items():
                t, out = time_batched(fn, lo, hi)
                import numpy as np

                docs = np.asarray(out[0])
                if ref is None:
                    ref = docs
                else:
                    np.testing.assert_array_equal(docs, ref)  # all engines agree
                rows.append(
                    [name, ename, k, len(ranges), round(bits / n, 3),
                     round(t * 1e6 / len(ranges), 1)]
                )
    return emit(rows, ["collection", "index", "k", "queries", "bits_per_char",
                       "us_per_query"])


if __name__ == "__main__":
    run()
