"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Sections:
    table1    collection statistics                     (Table 1)
    fig5      H' runs vs mutation rate                  (Fig 5)
    fig6      document listing time/space               (Figs 6-8)
    fig9      single-term top-k                         (Fig 9)
    fig10     document counting                         (Fig 10)
    table2    TF-IDF ranked multi-term throughput       (Table 2)
    serve     batched serving QPS / latency percentiles
    roofline  (arch x shape x mesh) roofline terms from the dry-run
"""

from __future__ import annotations

import argparse
import time


SECTIONS = ["table1", "fig5", "fig6", "fig9", "fig10", "table2", "serve", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=SECTIONS)
    args = ap.parse_args()
    todo = [args.only] if args.only else SECTIONS

    for section in todo:
        t0 = time.time()
        print(f"=== {section} " + "=" * 50)
        try:
            if section == "table1":
                from benchmarks import collection_stats

                collection_stats.run()
            elif section == "fig5":
                from benchmarks import sada_runs

                sada_runs.run()
            elif section == "fig6":
                from benchmarks import doc_listing

                doc_listing.run()
            elif section == "fig9":
                from benchmarks import topk

                topk.run()
            elif section == "fig10":
                from benchmarks import doc_counting

                doc_counting.run()
            elif section == "table2":
                from benchmarks import tfidf_bench

                tfidf_bench.run()
            elif section == "serve":
                from benchmarks import serve_bench

                serve_bench.run()
            elif section == "roofline":
                from benchmarks import roofline_report

                roofline_report.run()
        except Exception as e:  # noqa: BLE001
            print(f"[section {section} FAILED] {type(e).__name__}: {e}")
            raise
        print(f"--- {section} done in {time.time() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
