"""Roofline table from the dry-run JSONs (experiments/dryrun/*.json).

Per (arch x shape x mesh): the three terms in seconds, dominant term,
MODEL_FLOPS, analytic FLOPs, useful ratio, per-device memory."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load_results(root="experiments/dryrun"):
    results = []
    for path in sorted(glob.glob(os.path.join(root, "*.json"))):
        with open(path) as f:
            data = json.load(f)
        results.extend(data.get("results", []))
    return results


def run(root="experiments/dryrun"):
    results = load_results(root)
    if not results:
        print("no dry-run results found — run experiments/run_dryrun.sh first")
        return []
    rows = []
    for r in results:
        rl = r["roofline"]
        rows.append(
            [
                r["arch"],
                r["shape"],
                r["mesh"],
                f"{rl['compute_s']:.3e}",
                f"{rl['memory_s']:.3e}",
                f"{rl['collective_s']:.3e}",
                rl["dominant"],
                f"{rl['model_flops']:.3e}",
                f"{rl['useful_ratio']:.2f}",
                r["memory"]["temp_mb"],
                r["memory"].get("analytic_device_mb"),
            ]
        )
    return emit(
        rows,
        ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
         "dominant", "model_flops", "useful_ratio", "cpu_temp_mb",
         "analytic_dev_mb"],
    )


if __name__ == "__main__":
    run()
