"""End-to-end batched serving benchmark: QPS and latency percentiles.

Measures the planner stage plus the three planned endpoints (listing,
top-k, tf-idf) of ``RetrievalService`` at batch sizes {1, 16, 128} — each
batch is ONE compiled program per shape bucket, so after the first (warmup)
call per bucket the loop below is pure execution.  The ``plan`` endpoint
isolates the stage the fused backward-search kernel targets; it is timed
on whatever search path the service was built with (kernel on TPU, XLA
pair descent elsewhere — see benchmarks.backward_search_bench for the
per-path comparison).  Emits the usual CSV rows plus a dry-run-shaped JSON
({"results": [...], "failures": []}) at experiments/BENCH_serve.json so
the perf trajectory can track serving throughput next to the roofline
numbers.

    PYTHONPATH=src python -m benchmarks.serve_bench [--out experiments/BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import bench_collections, emit
from repro.data.collections import random_substring_patterns
from repro.serve.retrieval import RetrievalService

BATCH_SIZES = (1, 16, 128)
ITERS = 20


def _timed(fn, iters: int = ITERS, warmup: int = 1):
    # warmup: compiles the bucket's program; one full pass over the batch
    # cycle also settles the dispatch-aware brute windows (grow-only), so
    # the timed loop below is pure execution
    for _ in range(warmup):
        fn()
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        lat.append(time.perf_counter() - t0)
    ms = np.asarray(lat) * 1e3
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99)), float(ms.mean())


def run(collections=("version-p001", "dna-p03"), batch_sizes=BATCH_SIZES,
        k: int = 10, max_df: int = 128, max_buf: int = 1024,
        out: str | None = None, iters: int = ITERS):
    rows, results = [], []
    for name in collections:
        coll = bench_collections()[name]
        svc = RetrievalService.build(coll, block_size=32, beta=8.0)
        workload = random_substring_patterns(coll, 1500, 6, 256)
        if not workload:
            continue
        rng = np.random.default_rng(0)

        for B in batch_sizes:
            idx = rng.integers(0, len(workload), size=(iters + 1, B))
            batches = [[workload[i] for i in row] for row in idx]
            it = iter(range(10_000))

            def batch():
                return batches[next(it) % len(batches)]

            def pairs(b):
                return [b[i : i + 2] for i in range(0, len(b), 2)] or [b[:1]]

            endpoints = {
                "plan": lambda: svc.plan(batch()),
                "list": lambda: svc.list_docs(batch(), max_df=max_df, max_buf=max_buf),
                "topk": lambda: svc.topk(batch(), k=k, max_buf=max_buf),
                "tfidf": lambda: svc.tfidf(pairs(batch()), k=k, max_buf=max_buf),
            }
            for ep, fn in endpoints.items():
                p50, p99, mean = _timed(fn, iters=iters, warmup=iters + 1)
                nq = B if ep != "tfidf" else max(1, B // 2)
                qps = nq / (mean / 1e3)
                rows.append(
                    [name, ep, B, round(p50, 2), round(p99, 2), round(qps, 0)]
                )
                results.append(
                    {
                        "collection": name,
                        "endpoint": ep,
                        "batch": B,
                        "p50_ms": round(p50, 3),
                        "p99_ms": round(p99, 3),
                        "qps": round(qps, 1),
                        "compiles": dict(svc.compile_counts),
                    }
                )
    emit(rows, ["collection", "endpoint", "batch", "p50_ms", "p99_ms", "qps"])
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"results": results, "failures": []}, f, indent=1)
        print(f"wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/BENCH_serve.json")
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one collection, tiny batches, 3 iters")
    args = ap.parse_args()
    if args.smoke:
        run(collections=("version-p001",), batch_sizes=(1, 16), iters=3,
            out=args.out)
    else:
        run(batch_sizes=tuple(args.batches), out=args.out)


if __name__ == "__main__":
    main()
