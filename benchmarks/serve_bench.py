"""End-to-end batched serving benchmark: QPS and latency percentiles.

Measures the three planned endpoints (listing, top-k, tf-idf) of
``RetrievalService`` at batch sizes {1, 16, 128} — each batch is ONE
compiled program per shape bucket, so after the first (warmup) call per
bucket the loop below is pure execution.  Emits the usual CSV rows plus an
optional dry-run-shaped JSON ({"results": [...], "failures": []}) so the
perf trajectory can track serving throughput next to the roofline numbers.

    PYTHONPATH=src python -m benchmarks.serve_bench [--out experiments/serve_bench.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import bench_collections, emit
from repro.data.collections import random_substring_patterns
from repro.serve.retrieval import RetrievalService

BATCH_SIZES = (1, 16, 128)
ITERS = 20


def _timed(fn, iters: int = ITERS):
    fn()  # warmup: compiles the bucket's program
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        lat.append(time.perf_counter() - t0)
    ms = np.asarray(lat) * 1e3
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99)), float(ms.mean())


def run(collections=("version-p001", "dna-p03"), batch_sizes=BATCH_SIZES,
        k: int = 10, max_df: int = 128, max_buf: int = 1024, out: str | None = None):
    rows, results = [], []
    for name in collections:
        coll = bench_collections()[name]
        svc = RetrievalService.build(coll, block_size=32, beta=8.0)
        workload = random_substring_patterns(coll, 1500, 6, 256)
        if not workload:
            continue
        rng = np.random.default_rng(0)

        for B in batch_sizes:
            idx = rng.integers(0, len(workload), size=(ITERS + 1, B))
            batches = [[workload[i] for i in row] for row in idx]
            it = iter(range(10_000))

            def batch():
                return batches[next(it) % len(batches)]

            def pairs(b):
                return [b[i : i + 2] for i in range(0, len(b), 2)] or [b[:1]]

            endpoints = {
                "list": lambda: svc.list_docs(batch(), max_df=max_df, max_buf=max_buf),
                "topk": lambda: svc.topk(batch(), k=k, max_buf=max_buf),
                "tfidf": lambda: svc.tfidf(pairs(batch()), k=k, max_buf=max_buf),
            }
            for ep, fn in endpoints.items():
                p50, p99, mean = _timed(fn)
                nq = B if ep != "tfidf" else max(1, B // 2)
                qps = nq / (mean / 1e3)
                rows.append(
                    [name, ep, B, round(p50, 2), round(p99, 2), round(qps, 0)]
                )
                results.append(
                    {
                        "collection": name,
                        "endpoint": ep,
                        "batch": B,
                        "p50_ms": round(p50, 3),
                        "p99_ms": round(p99, 3),
                        "qps": round(qps, 1),
                        "compiles": dict(svc.compile_counts),
                    }
                )
    emit(rows, ["collection", "endpoint", "batch", "p50_ms", "p99_ms", "qps"])
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"results": results, "failures": []}, f, indent=1)
        print(f"wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    args = ap.parse_args()
    run(batch_sizes=tuple(args.batches), out=args.out)


if __name__ == "__main__":
    main()
