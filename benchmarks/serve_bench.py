"""End-to-end batched serving benchmark: QPS and latency percentiles.

Measures the planner stage plus the three planned endpoints (listing,
top-k, tf-idf) of ``RetrievalService`` at batch sizes {1, 16, 128} — each
batch is ONE compiled program per shape bucket, so after the first (warmup)
call per bucket the loop below is pure execution.  The ``plan`` endpoint
isolates the stage the fused backward-search kernel targets; it is timed
on whatever search path the service was built with (kernel on TPU, XLA
pair descent elsewhere — see benchmarks.backward_search_bench for the
per-path comparison).  Emits the usual CSV rows plus a dry-run-shaped JSON
({"results": [...], "failures": []}) at experiments/BENCH_serve.json so
the perf trajectory can track serving throughput next to the roofline
numbers.

A second section exercises the *resilient runtime* (``repro.serve.runtime``)
under deterministic fault injection: a 512-query workload is pushed through
``ServeRuntime`` while executor failures, hangs, and compile errors fire at
a seeded 10% rate, and the run must answer 100% of valid requests (degraded
answers flagged) with no deadline missed by more than one batch interval.
The JSON gains a ``"resilience"`` block with ``degraded_fraction`` and
``deadline_miss_rate``.

A third section sweeps the docs-mesh sharded service over shard counts
(``--shards``, default {1, 2, 4, 8} on a virtualized host mesh): each
result row carries a ``mesh_shape`` field, so the artifact records the
per-shard-count serving cost next to the single-device numbers.  The JSON
is written both to ``--out`` and to a repo-root ``BENCH_serve.json`` so
the perf trajectory is visible without digging into experiments/.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        [--out experiments/BENCH_serve.json] \
        [--shards 1 2 4 8] \
        [--inject executor_fail,slow_pdl,compile_error]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from benchmarks.common import SCALE, bench_collections, emit, write_json
from repro.analysis.jaxpr import count_primitive
from repro.data.collections import random_substring_patterns
from repro.kernels import ops
from repro.serve import faults
from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import RuntimeConfig, ServeRuntime

BATCH_SIZES = (1, 16, 128)
SHARD_COUNTS = (1, 2, 4, 8)
ITERS = 20
RESILIENCE_QUERIES = 512
DEFAULT_INJECT = "executor_fail,slow_pdl,compile_error"
#: fixed batch sizes for the kernel-vs-XLA listing comparison — NOT scaled
#: down in smoke runs, so the committed mirror's comparison rows stay
#: directly diffable across CI configurations
LIST_COMPARE_BATCHES = (16, 128)


def _build_service(coll, n_shards: int, **kw):
    """The service under test: plain at 1 shard, docs-mesh sharded above.

    Returns (service, mesh_shape) — ``mesh_shape`` goes verbatim into the
    result rows so the artifact distinguishes sweep points."""
    if n_shards <= 1:
        return RetrievalService.build(coll, **kw), [1]
    from repro.dist.sharding import make_docs_mesh

    mesh = make_docs_mesh(n_shards)
    return RetrievalService.build(coll, mesh=mesh, **kw), [n_shards]


def _timed(fn, iters: int = ITERS, warmup: int = 1):
    # warmup: compiles the bucket's program; one full pass over the batch
    # cycle also settles the dispatch-aware brute windows (grow-only), so
    # the timed loop below is pure execution
    for _ in range(warmup):
        fn()
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        lat.append(time.perf_counter() - t0)
    ms = np.asarray(lat) * 1e3
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99)), float(ms.mean())


def run_resilience(collection: str = "version-p001",
                   inject: str = DEFAULT_INJECT, rate: float = 0.1,
                   n_queries: int = RESILIENCE_QUERIES, batch: int = 8,
                   deadline_s: float = 0.5, seed: int = 0,
                   n_shards: int = 1) -> dict:
    """Push ``n_queries`` through ServeRuntime with faults firing at
    ``rate`` and report the resilience contract's metrics.  With
    ``n_shards > 1`` the runtime fronts the docs-mesh sharded service —
    the degradation ladder (retry, floor, host reference merge) must hold
    there too."""
    coll = bench_collections()[collection]
    # pin the Brute-L window: the grow-only dispatch-aware sizing would
    # recompile a bucket mid-run when a higher-occ pattern shows up, and
    # those compiles would read as deadline misses rather than resilience
    svc, mesh_shape = _build_service(coll, n_shards, block_size=32, beta=8.0,
                                     brute_window=512)
    workload = random_substring_patterns(coll, max(n_queries, 64), 6, 64)
    rng = np.random.default_rng(seed)
    rt = ServeRuntime(svc, RuntimeConfig(max_batch=batch,
                                         default_deadline_s=deadline_s))
    kinds = ("count", "list", "topk")
    rt.warmup(kinds=kinds, batch_sizes=(batch,))
    # a realistic warm wave per kind: settles the grow-only brute windows
    # (which recompile the bucket) and seeds the steady-state EMA, so the
    # measured run sees no in-flight compiles
    for kind in kinds:
        for _ in range(2):
            rt.serve([(kind, workload[int(i)])
                      for i in rng.integers(0, len(workload), size=batch)],
                     deadline_s=1e9)
    specs = faults.parse_fault_specs(inject, rate=rate, seed=seed)
    # workload-only baselines: warmup traffic above must not dilute the
    # resilience metrics
    m = rt.metrics
    base_submitted, base_answered = m.submitted, m.answered
    base_degraded, base_misses = m.degraded, m.deadline_misses
    served = 0
    batch_lat = []
    with faults.inject(*specs) as inj:
        while served < n_queries:
            # one kind per submission wave, so batches cut at the warmed
            # power-of-two bucket instead of fragmenting across kinds
            kind = kinds[(served // batch) % len(kinds)]
            take = min(batch, n_queries - served)
            t0 = time.perf_counter()
            for i in rng.integers(0, len(workload), size=take):
                rt.submit(kind, workload[int(i)])
                served += 1
            rt.run_until_idle()
            batch_lat.append(time.perf_counter() - t0)
    answered = m.answered - base_answered
    submitted = m.submitted - base_submitted
    interval_s = float(np.percentile(np.asarray(batch_lat), 99))
    res = {
        "collection": collection,
        "mesh_shape": mesh_shape,
        "inject": inject,
        "fault_rate": rate,
        "faults_fired": len(inj.fired),
        "queries": n_queries,
        "answered": answered,
        "answered_fraction": round(answered / submitted, 4),
        "degraded_fraction": round((m.degraded - base_degraded) / answered, 4),
        "deadline_miss_rate": round(
            (m.deadline_misses - base_misses) / answered, 4),
        "max_overrun_s": round(m.max_overrun_s, 4),
        "batch_interval_s": round(interval_s, 4),
        "overrun_within_one_interval": bool(m.max_overrun_s <= interval_s),
        "retries": m.retries,
        "breaker_trips": m.breaker_trips,
        "degrade_reasons": dict(m.degrade_reasons),
        "compile_s": m.as_dict()["compile_s"],
        "steady_ema_s": m.as_dict()["steady_ema_s"],
    }
    print("resilience:", json.dumps(res, indent=1))
    assert res["answered_fraction"] == 1.0, "runtime dropped valid requests"
    assert res["overrun_within_one_interval"], (
        f"deadline missed by {m.max_overrun_s:.3f}s > one batch interval "
        f"{interval_s:.3f}s"
    )
    return res


def _bench_endpoints(svc, name, mesh_shape, workload, batch_sizes,
                     k, max_df, max_buf, iters, rows, results):
    rng = np.random.default_rng(0)
    for B in batch_sizes:
        idx = rng.integers(0, len(workload), size=(iters + 1, B))
        batches = [[workload[i] for i in row] for row in idx]
        it = iter(range(10_000))

        def batch(batches=batches, it=it):
            return batches[next(it) % len(batches)]

        def pairs(b):
            return [b[i : i + 2] for i in range(0, len(b), 2)] or [b[:1]]

        endpoints = {
            "plan": lambda svc=svc, batch=batch: svc.plan(batch()),
            "list": lambda svc=svc, batch=batch: svc.list_docs(
                batch(), max_df=max_df, max_buf=max_buf),
            "topk": lambda svc=svc, batch=batch: svc.topk(batch(), k=k, max_buf=max_buf),
            "tfidf": lambda svc=svc, batch=batch, pairs=pairs: svc.tfidf(
                pairs(batch()), k=k, max_buf=max_buf),
        }
        for ep, fn in endpoints.items():
            p50, p99, mean = _timed(fn, iters=iters, warmup=iters + 1)
            nq = B if ep != "tfidf" else max(1, B // 2)
            qps = nq / (mean / 1e3)
            rows.append(
                [name, ep, B, mesh_shape[0],
                 round(p50, 2), round(p99, 2), round(qps, 0)]
            )
            results.append(
                {
                    "collection": name,
                    "endpoint": ep,
                    "batch": B,
                    "mesh_shape": mesh_shape,
                    "scale": SCALE,
                    "list_kernel":
                        "on" if getattr(svc, "use_list_kernel", False)
                        else "off",
                    "p50_ms": round(p50, 3),
                    "p99_ms": round(p99, 3),
                    "qps": round(qps, 1),
                    "compiles": dict(svc.compile_counts),
                }
            )


def run_list_kernel_comparison(collection: str, max_df: int = 128,
                               max_buf: int = 1024, iters: int = ITERS,
                               batches=LIST_COMPARE_BATCHES) -> tuple:
    """Kernel-vs-XLA listing rows at fixed batch sizes.

    The auto planner routes most patterns to Brute/PDL, so the default
    ``list`` rows barely exercise the ILCP executor — the honest kernel
    measurement also forces the ILCP engine (endpoint label
    ``list_ilcp``).  Every row carries the whole-program launch count and
    the per-launch resident + scratch VMEM bytes, so the artifact records
    the kernel's cost model next to its wall clock."""
    coll = bench_collections()[collection]
    workload = random_substring_patterns(coll, 1500, 6, 256)
    rows, results = [], []
    if not workload:
        return rows, results
    rng = np.random.default_rng(0)
    for mode, use_k in (("off", False), ("on", True)):
        svc = RetrievalService.build(
            coll, block_size=32, beta=8.0, use_list_kernel=use_k,
        )
        ilcp = svc.ilcp
        resident = ops.ilcp_list_resident_bytes(
            ilcp.vilcp, ilcp.rmq.table, ilcp.run_starts, svc.da
        )
        for B in batches:
            launches = count_primitive(
                svc.trace_endpoint("list", B=B, max_df=max_df).jaxpr,
                "pallas_call",
            )
            scratch = ops.ilcp_list_scratch_bytes(B, d=ilcp.d, max_df=max_df)
            idx = rng.integers(0, len(workload), size=(iters + 1, B))
            batches_q = [[workload[i] for i in row] for row in idx]
            it = iter(range(10_000))

            def batch(batches_q=batches_q, it=it):
                return batches_q[next(it) % len(batches_q)]

            for ep, eng in (("list", "auto"), ("list_ilcp", "ilcp")):
                p50, p99, mean = _timed(
                    lambda: svc.list_docs(batch(), max_df=max_df,
                                          engine=eng, max_buf=max_buf),
                    iters=iters, warmup=iters + 1,
                )
                qps = B / (mean / 1e3)
                rows.append([collection, ep, B, mode, launches,
                             round(p50, 2), round(p99, 2), round(qps, 0)])
                results.append({
                    "collection": collection,
                    "endpoint": ep,
                    "batch": B,
                    "mesh_shape": [1],
                    "scale": SCALE,
                    "list_kernel": mode,
                    "p50_ms": round(p50, 3),
                    "p99_ms": round(p99, 3),
                    "qps": round(qps, 1),
                    "list_launches": launches,
                    "list_resident_bytes": resident,
                    "list_scratch_bytes": scratch,
                })
    emit(rows, ["collection", "endpoint", "batch", "list_kernel",
                "launches", "p50_ms", "p99_ms", "qps"])
    return rows, results


def run(collections=("version-p001", "dna-p03"), batch_sizes=BATCH_SIZES,
        k: int = 10, max_df: int = 128, max_buf: int = 1024,
        out: str | None = None, iters: int = ITERS,
        inject: str = DEFAULT_INJECT, resilience_queries: int = RESILIENCE_QUERIES,
        shard_counts=SHARD_COUNTS, use_list_kernel: bool | None = None):
    rows, results = [], []
    for name in collections:
        coll = bench_collections()[name]
        svc = RetrievalService.build(coll, block_size=32, beta=8.0,
                                     use_list_kernel=use_list_kernel)
        workload = random_substring_patterns(coll, 1500, 6, 256)
        if not workload:
            continue
        _bench_endpoints(svc, name, [1], workload, batch_sizes,
                         k, max_df, max_buf, iters, rows, results)

    # shard-count sweep on the first collection: the same endpoints through
    # the docs-mesh service, one row per (endpoint, batch, mesh shape).
    # Shard counts past the (virtualized) device count are skipped loudly —
    # the artifact's mesh_shape column shows exactly what ran.
    feasible = [s for s in shard_counts if 1 < s <= jax.device_count()]
    skipped = [s for s in shard_counts if s > jax.device_count()]
    if skipped:
        print(f"shard sweep: skipping {skipped} "
              f"(only {jax.device_count()} devices)")
    sweep_coll = bench_collections()[collections[0]]
    sweep_load = random_substring_patterns(sweep_coll, 1500, 6, 256)
    for n_shards in feasible:
        svc, mesh_shape = _build_service(
            sweep_coll, n_shards, block_size=32, beta=8.0, brute_window=512,
            use_list_kernel=use_list_kernel,
        )
        _bench_endpoints(svc, collections[0], mesh_shape, sweep_load,
                         batch_sizes, k, max_df, max_buf, iters, rows, results)

    emit(rows, ["collection", "endpoint", "batch", "shards",
                "p50_ms", "p99_ms", "qps"])
    # kernel-vs-XLA listing comparison at fixed batches (see the function's
    # docstring); its rows join the artifact so the perf trajectory can
    # diff the kernel path against the committed mirror
    _, cmp_results = run_list_kernel_comparison(
        collections[0], max_df=max_df, max_buf=max_buf, iters=iters,
    )
    results.extend(cmp_results)
    # resilience: unsharded, plus through the widest sharded service built
    resilience = run_resilience(collection=collections[0], inject=inject,
                                n_queries=resilience_queries)
    resilience_sharded = None
    if feasible:
        resilience_sharded = run_resilience(
            collection=collections[0], inject=inject,
            n_queries=resilience_queries, n_shards=max(feasible),
        )
    payload = {
        "results": results,
        "resilience": resilience,
        "resilience_sharded": resilience_sharded,
        "device_count": jax.device_count(),
        "scale": SCALE,
        "list_kernel_batches": list(LIST_COMPARE_BATCHES),
        "failures": [],
    }
    write_json(out, payload, "BENCH_serve.json")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/BENCH_serve.json")
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--shards", type=int, nargs="*", default=list(SHARD_COUNTS),
                    help="docs-mesh shard counts to sweep (1 = unsharded; "
                         "counts past the device count are skipped)")
    ap.add_argument("--inject", default=DEFAULT_INJECT,
                    help="fault specs for the resilience section "
                         "(repro.serve.faults names, 'name[:rate]' comma list)")
    ap.add_argument("--list-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="listing backend for the main endpoint rows: "
                         "'auto' follows the platform (kernel on TPU), "
                         "'on'/'off' force it; the kernel-vs-XLA comparison "
                         "block always benches both")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one collection, tiny batches, 3 iters")
    args = ap.parse_args()
    lk = {"auto": None, "on": True, "off": False}[args.list_kernel]
    if args.smoke:
        run(collections=("version-p001",), batch_sizes=(1, 16), iters=3,
            out=args.out, inject=args.inject, resilience_queries=128,
            shard_counts=tuple(args.shards), use_list_kernel=lk)
    else:
        run(batch_sizes=tuple(args.batches), out=args.out, inject=args.inject,
            shard_counts=tuple(args.shards), use_list_kernel=lk)


if __name__ == "__main__":
    main()
