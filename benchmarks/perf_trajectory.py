"""Perf-trajectory gate: fresh bench JSON vs the committed mirror.

The repo root keeps the latest bench artifacts (``BENCH_serve.json``,
``BENCH_backward_search.json``) committed next to ROADMAP.md.  This module
diffs a freshly generated ``experiments/BENCH_*.json`` against that
committed baseline and FAILS when any matching row regresses its latency
metric by more than ``--threshold`` (default 25%) — so a PR that silently
doubles an endpoint's p50 turns CI red even though every correctness test
still passes.

Matching is strict: a fresh row is compared only to a baseline row with
the same (collection, endpoint-or-variant, batch, mesh_shape, scale,
list_kernel) key.  ``scale`` keeps rows produced under different
``REPRO_BENCH_SCALE`` CI steps from being compared to each other;
``list_kernel`` (defaulting "off" for rows that predate the fused listing
kernel) keeps the kernel-vs-XLA comparison rows separate.  Rows whose
baseline is below ``--min-ms`` are skipped — a 25% swing on a 20-microsecond
row is scheduler noise, not a regression.  Zero matching rows is a loud
warning, not a failure: the first run after a row-schema change has
nothing to diff against until the mirror is refreshed.

    PYTHONPATH=src python -m benchmarks.perf_trajectory \
        --fresh experiments/BENCH_serve_sharded.json \
        --baseline /tmp/committed_BENCH_serve.json \
        [--threshold 0.25] [--min-ms 0.05]

In CI the baseline must come from ``git show HEAD:BENCH_serve.json`` — the
bench steps earlier in the job overwrite the repo-root mirrors in the
working tree.
"""

from __future__ import annotations

import argparse
import json
import sys

#: latency fields tried in order — serve rows carry p50_ms, the
#: backward-search roofline rows carry median_ms
METRICS = ("p50_ms", "median_ms")


def _row_key(row: dict):
    return (
        row.get("collection"),
        row.get("endpoint") or row.get("variant"),
        row.get("batch"),
        tuple(row.get("mesh_shape") or ()),
        row.get("scale"),
        row.get("list_kernel", "off"),
    )


def _metric(row: dict):
    for name in METRICS:
        if name in row:
            return name, float(row[name])
    return None, None


def _rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    out = {}
    for row in payload.get("results", []):
        name, value = _metric(row)
        if name is None:
            continue
        out[_row_key(row)] = (name, value)
    return out


def compare(fresh: dict, baseline: dict, threshold: float,
            min_ms: float) -> tuple[list, list]:
    """Returns (regressions, compared): regressions as printable dicts,
    compared as the matched keys — empty ``compared`` means the schemas
    diverged and the gate has nothing to say."""
    regressions, compared = [], []
    for key, (name, fresh_ms) in fresh.items():
        if key not in baseline:
            continue
        base_name, base_ms = baseline[key]
        if base_name != name or base_ms < min_ms:
            continue
        compared.append(key)
        if fresh_ms > base_ms * (1.0 + threshold):
            regressions.append({
                "key": key,
                "metric": name,
                "baseline_ms": base_ms,
                "fresh_ms": fresh_ms,
                "ratio": round(fresh_ms / base_ms, 3),
            })
    return regressions, compared


def run(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.perf_trajectory",
        description="fail CI when a bench row regresses vs the committed "
                    "mirror",
    )
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_*.json")
    ap.add_argument("--baseline", required=True,
                    help="committed mirror (use `git show HEAD:...` in CI "
                         "— the bench steps overwrite the working tree)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional p50 regression (default 0.25)")
    ap.add_argument("--min-ms", type=float, default=0.05,
                    help="skip rows whose baseline is below this (noise "
                         "floor, default 0.05 ms)")
    args = ap.parse_args(argv)

    fresh = _rows(args.fresh)
    baseline = _rows(args.baseline)
    regressions, compared = compare(fresh, baseline, args.threshold,
                                    args.min_ms)

    if not compared:
        print(f"perf_trajectory: WARNING — no comparable rows between "
              f"{args.fresh} ({len(fresh)} rows) and {args.baseline} "
              f"({len(baseline)} rows); refresh the committed mirror",
              file=sys.stderr)
        return 0
    for r in regressions:
        coll, ep, batch, mesh, scale, lk = r["key"]
        print(f"REGRESSION {coll}/{ep} B={batch} mesh={list(mesh)} "
              f"scale={scale} list_kernel={lk}: {r['metric']} "
              f"{r['baseline_ms']:.3f} -> {r['fresh_ms']:.3f} ms "
              f"({r['ratio']}x)", file=sys.stderr)
    print(f"perf_trajectory: {len(compared)} rows compared, "
          f"{len(regressions)} regression(s) past "
          f"{args.threshold:.0%} (noise floor {args.min_ms} ms)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(run())
