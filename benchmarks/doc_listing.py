"""Figures 6-8: document listing — time per query vs index bits/char.

Indexes (Section 6.2.1): Brute-L, Brute-D, Sada-C-D, Sada-I-D (ILCP),
Sada-I-L, PDL.  Query time excludes range finding, as in the paper; space
is the modeled compressed size of the *listing structure* (the CSA is
reported separately by collection_stats).

``--list-kernel`` adds fused-ILCP comparison rows: the same Fig-1
recursion through ``ilcp_list_docs_da_planned`` as one Pallas launch
(``on``), as the XLA lockstep fallback (``off``), or both (``auto``,
the default) — each row carries its whole-program ``pallas_call`` count
and the kernel's per-launch resident + scratch VMEM bytes."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import (
    bench_collections, emit, patterns_for, suffix_data_for, time_batched,
)
from repro.analysis.jaxpr import count_primitive
from repro.core.csa import build_csa
from repro.core.ilcp import (
    build_ilcp,
    ilcp_list_docs_csa,
    ilcp_list_docs_da,
    ilcp_list_docs_da_planned,
)
from repro.core.listing import brute_list_csa, brute_list_da, sada_c_list_docs_da
from repro.core.pdl import build_pdl, pdl_list_docs
from repro.core.wtlist import build_da_wavelet, wt_list_docs, wt_modeled_bits
from repro.kernels import ops
from repro.succinct.rmq import rmq_build
from repro.common import ceil_log2


def run(collections=("dna-p001", "dna-p03", "version-p001", "random"),
        list_kernel: str = "auto"):
    rows = []
    for name in collections:
        coll = bench_collections()[name]
        data = suffix_data_for(name)
        csa = build_csa(data)
        ilcp = build_ilcp(data)
        pdl = build_pdl(data, block_size=64, beta=16.0, mode="list")
        rmq_c = rmq_build(data.c)
        da = jnp.asarray(data.da)
        da_wm = build_da_wavelet(data.da, coll.d)
        pats, ranges = patterns_for(name)
        nz = ranges[:, 1] > ranges[:, 0]
        ranges = ranges[nz]
        if not len(ranges):
            continue
        lo = jnp.asarray(ranges[:, 0])
        hi = jnp.asarray(ranges[:, 1])
        max_df = coll.d + 1
        max_occ = min(int((ranges[:, 1] - ranges[:, 0]).max()), 8192)
        n = coll.n
        total_df = sum(
            len(set(data.da[a:b].tolist())) for a, b in ranges
        )

        da_bits = n * max(1, ceil_log2(coll.d))
        engines = {
            "Brute-L": (
                jax.jit(jax.vmap(lambda a, b, csa=csa, mo=max_occ, md=max_df: brute_list_csa(csa, a, b, mo, md)[:2])),
                0,
            ),
            "Brute-D": (
                jax.jit(jax.vmap(lambda a, b, da=da, mo=max_occ, md=max_df: brute_list_da(da, a, b, mo, md)[:2])),
                da_bits,
            ),
            "Sada-C-D": (
                jax.jit(jax.vmap(lambda a, b, rmq_c=rmq_c, da=da, d=coll.d, md=max_df: sada_c_list_docs_da(rmq_c, da, a, b, d, md))),
                da_bits + 2 * n,
            ),
            "Sada-I-D": (
                jax.jit(jax.vmap(lambda a, b, ilcp=ilcp, da=da, md=max_df: ilcp_list_docs_da(ilcp, da, a, b, md))),
                da_bits + ilcp.modeled_bits_listing(),
            ),
            "Sada-I-L": (
                jax.jit(jax.vmap(lambda a, b, ilcp=ilcp, csa=csa, md=max_df: ilcp_list_docs_csa(ilcp, csa, a, b, md))),
                ilcp.modeled_bits_listing(),
            ),
            "PDL": (
                jax.jit(jax.vmap(lambda a, b, pdl=pdl, csa=csa, md=max_df: pdl_list_docs(pdl, csa, a, b, md, max_buf=2048))),
                pdl.modeled_bits(),
            ),
            "WT": (
                jax.jit(jax.vmap(lambda a, b, da_wm=da_wm, md=max_df: wt_list_docs(da_wm, a, b, md)[::2])),
                wt_modeled_bits(da_wm),
            ),
        }
        for ename, (fn, bits) in engines.items():
            t, out = time_batched(fn, lo, hi)
            us_per_doc = t * 1e6 / max(total_df, 1)
            rows.append(
                [name, ename, len(ranges), round(bits / n, 3),
                 round(t * 1e3, 2), round(us_per_doc, 2), 0, 0, 0]
            )

        # fused-ILCP comparison rows: one Pallas launch for the whole
        # batch (on) vs the XLA lockstep fallback (off), same bit pattern
        ilcp_bits = da_bits + ilcp.modeled_bits_listing()
        modes = {"auto": (False, True), "on": (True,), "off": (False,)}
        resident = ops.ilcp_list_resident_bytes(
            ilcp.vilcp, ilcp.rmq.table, ilcp.run_starts, da
        )
        scratch = ops.ilcp_list_scratch_bytes(
            int(lo.shape[0]), d=coll.d, max_df=max_df
        )
        for use_k in modes[list_kernel]:
            fn = jax.jit(
                lambda a, b, ilcp=ilcp, da=da, md=max_df, uk=use_k:
                ilcp_list_docs_da_planned(ilcp, da, a, b, md, use_kernel=uk)
            )
            launches = count_primitive(
                jax.make_jaxpr(fn)(lo, hi).jaxpr, "pallas_call"
            )
            t, out = time_batched(fn, lo, hi)
            us_per_doc = t * 1e6 / max(total_df, 1)
            label = f"Sada-I-D-fused[{'on' if use_k else 'off'}]"
            rows.append(
                [name, label, len(ranges), round(ilcp_bits / n, 3),
                 round(t * 1e3, 2), round(us_per_doc, 2), launches,
                 resident if use_k else 0, scratch if use_k else 0]
            )
    return emit(rows, ["collection", "index", "queries", "bits_per_char",
                       "batch_ms", "us_per_result", "pallas_calls",
                       "resident_bytes", "scratch_bytes"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--list-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="fused-ILCP comparison rows: 'auto' benches both "
                         "backends, 'on'/'off' just one")
    ap.add_argument("--collections", nargs="*",
                    default=["dna-p001", "dna-p03", "version-p001", "random"])
    args = ap.parse_args()
    run(collections=tuple(args.collections), list_kernel=args.list_kernel)


if __name__ == "__main__":
    main()
