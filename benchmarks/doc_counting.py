"""Fig 10: document counting — time vs bits/char for the Sadakane encoding
family (plain / RR / S / S-S / F-P) and ILCP counting."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_collections, emit, patterns_for, suffix_data_for, time_batched
from repro.core.ilcp import build_ilcp, ilcp_count_docs_batch
from repro.core.sada import VARIANTS, build_sada, sada_count_batch


def run(collections=("dna-p001", "version-p001", "version-p01", "random")):
    rows = []
    for name in collections:
        coll = bench_collections()[name]
        data = suffix_data_for(name)
        pats, ranges = patterns_for(name)
        nz = ranges[:, 1] > ranges[:, 0]
        ranges = ranges[nz]
        if not len(ranges):
            continue
        lo = jnp.asarray(ranges[:, 0])
        hi = jnp.asarray(ranges[:, 1])
        lens = jnp.asarray([len(p) for p, keep in zip(pats, nz) if keep], jnp.int32)
        n = coll.n

        expected = None
        for variant in VARIANTS:
            s = build_sada(data, variant)
            fn = jax.jit(lambda a, b, s=s: sada_count_batch(s, a, b))
            t, out = time_batched(fn, lo, hi)
            if expected is None:
                expected = np.asarray(out)
            else:
                np.testing.assert_array_equal(np.asarray(out), expected)
            rows.append(
                [name, f"Sada-{variant}", len(ranges),
                 round(s.modeled_bits() / n, 3),
                 round(t * 1e6 / len(ranges), 2)]
            )
        ilcp = build_ilcp(data)
        fn = jax.jit(lambda a, b, m, ilcp=ilcp: ilcp_count_docs_batch(ilcp, a, b, m))
        t, out = time_batched(fn, lo, hi, lens)
        np.testing.assert_array_equal(np.asarray(out), expected)
        rows.append(
            [name, "ILCP", len(ranges),
             round(ilcp.modeled_bits_counting() / n, 3),
             round(t * 1e6 / len(ranges), 2)]
        )
    return emit(rows, ["collection", "index", "queries", "bits_per_char",
                       "us_per_query"])


if __name__ == "__main__":
    run()
