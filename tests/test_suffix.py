"""Tests for suffix machinery: SA (prefix doubling vs naive), LCP, DA, C,
ILCP (against the paper's running example and naive oracles), and the CSA
(backward search + locate vs the suffix array)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    naive_lcp_of,
    naive_suffix_array,
    sa_range_for_pattern,
)
from repro.core.csa import (
    build_csa,
    csa_da_at,
    csa_lookup,
    csa_lookup_batch,
    csa_search,
    csa_search_batch,
)

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# The paper's running example (Section 3.1)
# ---------------------------------------------------------------------------

PAPER_DOCS = ["TATA", "LATA", "AAAA"]  # paper writes them with trailing $


@pytest.fixture(scope="module")
def paper_data():
    coll = concat_documents(PAPER_DOCS)
    return build_suffix_data(coll)


def test_paper_example_sa(paper_data):
    # Paper (1-based): SA = <15,10,5,14,9,4,13,12,11,7,2,6,8,3,1>
    expected = np.asarray([15, 10, 5, 14, 9, 4, 13, 12, 11, 7, 2, 6, 8, 3, 1]) - 1
    np.testing.assert_array_equal(paper_data.sa, expected)


def test_paper_example_da(paper_data):
    # Paper: DA = <3,2,1,3,2,1,3,3,3,2,1,2,2,1,1> (1-based doc ids)
    expected = np.asarray([3, 2, 1, 3, 2, 1, 3, 3, 3, 2, 1, 2, 2, 1, 1]) - 1
    np.testing.assert_array_equal(paper_data.da, expected)


def test_paper_example_ilcp(paper_data):
    # Paper: ILCP = <0,0,0,0,0,0,1,2,3,1,1,0,0,0,2>
    expected = np.asarray([0, 0, 0, 0, 0, 0, 1, 2, 3, 1, 1, 0, 0, 0, 2])
    np.testing.assert_array_equal(paper_data.ilcp, expected)


def test_paper_example_pattern_range(paper_data):
    # P = "TA" -> SA[13..15] (1-based) = [12, 15) 0-based
    lo, hi = sa_range_for_pattern(paper_data, encode_pattern("TA"))
    assert (lo, hi) == (12, 15)
    # ILCP[12:15] = <0, 0, 2>; values < |P|=2 at positions 12, 13 -> docs 2, 1
    assert paper_data.ilcp[12:15].tolist() == [0, 0, 2]
    assert paper_data.da[12:15].tolist() == [1, 0, 0]


# ---------------------------------------------------------------------------
# Randomized SA / LCP / ILCP correctness
# ---------------------------------------------------------------------------


def random_docs(n_docs, max_len, sigma, repetitive=False):
    docs = []
    if repetitive:
        base = RNG.integers(0, sigma, RNG.integers(4, max_len)).astype(np.int32)
        for _ in range(n_docs):
            doc = base.copy()
            nmut = max(1, len(doc) // 10)
            pos = RNG.integers(0, len(doc), nmut)
            doc[pos] = RNG.integers(0, sigma, nmut)
            docs.append(doc)
    else:
        for _ in range(n_docs):
            docs.append(RNG.integers(0, sigma, RNG.integers(1, max_len)).astype(np.int32))
    return docs


@pytest.mark.parametrize("repetitive", [False, True])
@pytest.mark.parametrize("sigma", [2, 4, 26])
def test_sa_matches_naive(sigma, repetitive):
    docs = random_docs(5, 20, sigma, repetitive)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    np.testing.assert_array_equal(data.sa, naive_suffix_array(coll))


def test_lcp_matches_naive():
    docs = random_docs(4, 15, 3, repetitive=True)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    for i in range(1, coll.n):
        exp = naive_lcp_of(coll, int(data.sa[i - 1]), int(data.sa[i]))
        assert data.lcp[i] == exp, i


def test_ilcp_matches_per_document_lcp():
    """Definition 1 checked directly: build each document's own LCP array
    and interleave by DA."""
    docs = random_docs(4, 12, 3, repetitive=True)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)

    expected = np.zeros(coll.n, dtype=np.int32)
    for j, doc in enumerate(docs):
        sub = concat_documents([doc])
        sub_data = build_suffix_data(sub)
        # positions in global SA belonging to doc j, in SA order
        mask = data.da == j
        # LCP array of the single document (its SA order matches, Lemma 1)
        expected[mask] = sub_data.lcp
    np.testing.assert_array_equal(data.ilcp, expected)


def test_c_array_definition():
    docs = random_docs(4, 12, 3)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    for i in range(coll.n):
        prev = -1
        for h in range(i - 1, -1, -1):
            if data.da[h] == data.da[i]:
                prev = h
                break
        assert data.c[i] == prev


@settings(max_examples=20, deadline=None)
@given(st.lists(st.text(alphabet="ab", min_size=1, max_size=12), min_size=1, max_size=5))
def test_sa_property_strings(docs):
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    np.testing.assert_array_equal(data.sa, naive_suffix_array(coll))
    # SA must be a permutation; LCP sanity
    assert sorted(data.sa.tolist()) == list(range(coll.n))
    assert (data.ilcp >= 0).all() and (data.ilcp <= coll.n).all()


# ---------------------------------------------------------------------------
# CSA: backward search and locate
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def csa_fixture():
    docs = ["mississippi", "missouri", "mission", "miss", "sippi", "pimiss"] * 2
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    csa = build_csa(data, sample_rate=4)
    return coll, data, csa


def test_csa_search_matches_sa_binary_search(csa_fixture):
    coll, data, csa = csa_fixture
    patterns = ["iss", "ssi", "m", "miss", "pi", "q", "mississippi", "x", "i"]
    max_m = max(len(p) for p in patterns)
    padded = np.zeros((len(patterns), max_m), dtype=np.int32)
    lengths = np.zeros(len(patterns), dtype=np.int32)
    for qi, p in enumerate(patterns):
        enc = encode_pattern(p)
        padded[qi, : len(enc)] = enc
        lengths[qi] = len(enc)
    lo, hi = csa_search_batch(csa, padded, lengths)
    for qi, p in enumerate(patterns):
        exp = sa_range_for_pattern(data, encode_pattern(p))
        assert (int(lo[qi]), int(hi[qi])) == exp, p


def test_csa_lookup_matches_sa(csa_fixture):
    coll, data, csa = csa_fixture
    idx = jnp.arange(coll.n)
    got = np.asarray(csa_lookup_batch(csa, idx))
    np.testing.assert_array_equal(got, data.sa)


def test_csa_da_matches(csa_fixture):
    coll, data, csa = csa_fixture
    got = np.asarray(jax.vmap(lambda i: csa_da_at(csa, i))(jnp.arange(coll.n)))
    np.testing.assert_array_equal(got, data.da)


def test_csa_search_empty_and_missing(csa_fixture):
    coll, data, csa = csa_fixture
    lo, hi = csa_search(csa, jnp.zeros(4, jnp.int32), 0)
    assert (int(lo), int(hi)) == (0, coll.n)
    enc = encode_pattern("zzz")
    pat = np.zeros(4, dtype=np.int32)
    pat[: len(enc)] = enc
    lo, hi = csa_search(csa, pat, 3)
    assert int(lo) == int(hi)


def test_csa_modeled_sizes(csa_fixture):
    coll, data, csa = csa_fixture
    assert csa.bwt_runs < coll.n  # repetitive-ish: BWT must have runs
    assert csa.modeled_bits_rlcsa() > 0
    assert csa.modeled_bits_plain_fm() > 0


def test_csa_repetitive_runs_shrink():
    """RLCSA's premise: BWT runs grow with edits, not with copies."""
    base = "".join(RNG.choice(list("acgt"), 200))
    docs_rep = [base] * 20
    mutated = []
    for _ in range(20):
        b = list(base)
        for _ in range(3):
            b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
        mutated.append("".join(b))
    runs_copies = build_csa(build_suffix_data(concat_documents(docs_rep))).bwt_runs
    runs_mut = build_csa(build_suffix_data(concat_documents(mutated))).bwt_runs
    n = sum(len(d) + 1 for d in docs_rep)
    assert runs_copies < n / 4
    assert runs_copies <= runs_mut
