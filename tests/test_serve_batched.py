"""Batched query-engine tests: the planned, masked, jit-compiled pipeline
must be bit-identical to the ``engine="reference"`` per-query path on
randomized repetitive collections, and the shape-bucketing cache must
compile at most once per bucket."""

import numpy as np
import jax
import pytest

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.serve.planner import (
    ENGINE_BRUTE,
    ENGINE_EMPTY,
    ENGINE_ILCP,
    ENGINE_PDL,
)
from repro.serve.retrieval import RetrievalService

MAX_BUF = 512

SPECS = {
    "version": SyntheticSpec("version", n_base=3, n_variants=7, base_len=90,
                             mutation_rate=0.01, seed=5),
    "dna": SyntheticSpec("dna", n_base=1, n_variants=16, base_len=150,
                         mutation_rate=0.003, seed=9),
}


@pytest.fixture(scope="module", params=list(SPECS))
def svc_pats(request):
    coll = generate(SPECS[request.param])
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    pats = random_substring_patterns(coll, 300, 5, 24)
    assert pats, "workload generation produced no patterns"
    return svc, pats


# ---------------------------------------------------------------------------
# Parity: batched pipeline == reference per-query path, all engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["auto", "brute", "ilcp", "pdl"])
def test_list_docs_parity(svc_pats, engine):
    svc, pats = svc_pats
    got = svc.list_docs(pats[:10], max_df=64, engine=engine, max_buf=MAX_BUF)
    ref = svc.list_docs(
        pats[:10], max_df=64, engine=f"reference:{engine}", max_buf=MAX_BUF
    )
    assert got == ref


@pytest.mark.parametrize("engine", ["auto", "brute", "pdl"])
def test_topk_parity(svc_pats, engine):
    svc, pats = svc_pats
    got = svc.topk(pats[:10], k=5, engine=engine, max_buf=MAX_BUF)
    ref = svc.topk(pats[:10], k=5, engine=f"reference:{engine}", max_buf=MAX_BUF)
    assert got == ref


@pytest.mark.parametrize("conjunctive", [False, True])
def test_tfidf_parity(svc_pats, conjunctive):
    svc, pats = svc_pats
    queries = [[pats[0], pats[1]], [pats[2]], [pats[3], pats[0], pats[2]]]
    got = svc.tfidf(queries, k=5, conjunctive=conjunctive, max_buf=MAX_BUF)
    ref = svc.tfidf(
        queries, k=5, conjunctive=conjunctive, max_buf=MAX_BUF,
        engine="reference",
    )
    assert got == ref


def test_missing_pattern_is_empty(svc_pats):
    svc, pats = svc_pats
    # a symbol outside the collection alphabet never occurs; a zero-length
    # pattern is empty by the serving contract (not the full range)
    bogus = np.full(6, svc.coll.sigma + 3, np.int32)
    empty = np.zeros(0, np.int32)
    batch = [pats[0], bogus, pats[1], empty]
    got = svc.list_docs(batch, max_df=32, max_buf=MAX_BUF)
    ref = svc.list_docs(batch, max_df=32, engine="reference", max_buf=MAX_BUF)
    assert got == ref
    assert got[1] == [] and got[3] == []
    assert svc.topk(batch, k=3, max_buf=MAX_BUF)[1] == []
    assert int(svc.count(batch)[1]) == 0 and int(svc.count(batch)[3]) == 0


def test_plan_engine_assignment(svc_pats):
    svc, pats = svc_pats
    plan = svc.plan(pats[:12])
    assert set(plan["engine"]).issubset(
        {ENGINE_EMPTY, ENGINE_BRUTE, ENGINE_ILCP, ENGINE_PDL}
    )
    nonempty = plan["occ"] > 0
    # auto never assigns ILCP (the paper's recommendation is brute-vs-PDL)
    assert np.all(np.isin(plan["engine"][nonempty], [ENGINE_BRUTE, ENGINE_PDL]))
    forced = svc.plan(pats[:12], engine="ilcp")
    assert np.all(forced["engine"][nonempty] == ENGINE_ILCP)
    # the policy itself: occ < threshold * df -> brute
    occ, df = plan["occ"][nonempty], np.maximum(plan["df"][nonempty], 1)
    want = np.where(occ < svc.occ_df_threshold * df, ENGINE_BRUTE, ENGINE_PDL)
    assert np.array_equal(plan["engine"][nonempty], want)


def test_count_matches_truth(svc_pats):
    svc, pats = svc_pats
    from repro.core.suffix import build_suffix_data, sa_range_for_pattern

    data = build_suffix_data(svc.coll)
    got = svc.count(pats[:12])
    for i, p in enumerate(pats[:12]):
        lo, hi = sa_range_for_pattern(data, p)
        assert int(got[i]) == len(set(data.da[lo:hi].tolist()))


# ---------------------------------------------------------------------------
# Shape-bucketing compile cache
# ---------------------------------------------------------------------------


def test_one_compile_per_bucket():
    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=5, base_len=80,
                      mutation_rate=0.01, seed=11)
    )
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    pats = random_substring_patterns(coll, 200, 5, 16)
    assert len(pats) >= 9

    compile_events = []
    recording = []
    jax.monitoring.register_event_listener(
        lambda name, **kw: compile_events.append(name)
        if recording and "compile" in name
        else None
    )

    # batch sizes 5 and 7 land in the same power-of-two bucket (8)
    svc.list_docs(pats[:5], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 1

    recording.append(True)  # arm the listener: bucket is warm now
    out7 = svc.list_docs(pats[:7], max_df=32, max_buf=MAX_BUF)
    out5 = svc.list_docs(pats[:5], max_df=32, engine="pdl", max_buf=MAX_BUF)
    recording.clear()

    assert svc.compile_counts["list"] == 1, "same bucket must not recompile"
    assert not compile_events, f"hot path triggered XLA compiles: {compile_events}"
    assert len(out7) == 7 and len(out5) == 5

    # a new bucket (16) compiles exactly once more
    svc.list_docs(pats[:9], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2
    svc.list_docs(pats[:16], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2

    # engine mode is traced, not static: no recompile across engines
    for engine in ("auto", "brute", "ilcp", "pdl"):
        svc.list_docs(pats[:7], max_df=32, engine=engine, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2

    # other endpoints keep their own per-bucket tally
    svc.topk(pats[:5], k=3, max_buf=MAX_BUF)
    svc.topk(pats[:8], k=3, max_buf=MAX_BUF)
    assert svc.compile_counts["topk"] == 1
    svc.tfidf([[pats[0], pats[1]]], k=3, max_buf=MAX_BUF)
    svc.tfidf([[pats[2]]], k=3, max_buf=MAX_BUF)
    assert svc.compile_counts["tfidf"] == 1


def test_empty_batch():
    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=4, base_len=60,
                      mutation_rate=0.01, seed=3)
    )
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    assert svc.list_docs([]) == []
    assert svc.topk([]) == []
    assert svc.tfidf([]) == []
    assert svc.count([]).shape == (0,)
