"""Batched query-engine tests: the planned, masked, jit-compiled pipeline
must be bit-identical to the ``engine="reference"`` per-query path on
randomized repetitive collections, and the shape-bucketing cache must
compile at most once per bucket."""

import numpy as np
import jax
import pytest

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.serve.planner import (
    ENGINE_BRUTE,
    ENGINE_EMPTY,
    ENGINE_ILCP,
    ENGINE_PDL,
)
from repro.serve.retrieval import BRUTE_WINDOW_FLOOR, RetrievalService

MAX_BUF = 512

SPECS = {
    "version": SyntheticSpec("version", n_base=3, n_variants=7, base_len=90,
                             mutation_rate=0.01, seed=5),
    "dna": SyntheticSpec("dna", n_base=1, n_variants=16, base_len=150,
                         mutation_rate=0.003, seed=9),
}


@pytest.fixture(scope="module", params=list(SPECS))
def svc_pats(request):
    coll = generate(SPECS[request.param])
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    pats = random_substring_patterns(coll, 300, 5, 24)
    assert pats, "workload generation produced no patterns"
    return svc, pats


# ---------------------------------------------------------------------------
# Parity: batched pipeline == reference per-query path, all engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["auto", "brute", "ilcp", "pdl"])
def test_list_docs_parity(svc_pats, engine):
    svc, pats = svc_pats
    got = svc.list_docs(pats[:10], max_df=64, engine=engine, max_buf=MAX_BUF)
    ref = svc.list_docs(
        pats[:10], max_df=64, engine=f"reference:{engine}", max_buf=MAX_BUF
    )
    assert got == ref


@pytest.mark.parametrize("engine", ["auto", "brute", "pdl"])
def test_topk_parity(svc_pats, engine):
    svc, pats = svc_pats
    got = svc.topk(pats[:10], k=5, engine=engine, max_buf=MAX_BUF)
    ref = svc.topk(pats[:10], k=5, engine=f"reference:{engine}", max_buf=MAX_BUF)
    assert got == ref


@pytest.mark.parametrize("conjunctive", [False, True])
def test_tfidf_parity(svc_pats, conjunctive):
    svc, pats = svc_pats
    queries = [[pats[0], pats[1]], [pats[2]], [pats[3], pats[0], pats[2]]]
    got = svc.tfidf(queries, k=5, conjunctive=conjunctive, max_buf=MAX_BUF)
    ref = svc.tfidf(
        queries, k=5, conjunctive=conjunctive, max_buf=MAX_BUF,
        engine="reference",
    )
    assert got == ref


def test_missing_pattern_is_empty(svc_pats):
    svc, pats = svc_pats
    # a symbol outside the collection alphabet never occurs; a zero-length
    # pattern is empty by the serving contract (not the full range)
    bogus = np.full(6, svc.coll.sigma + 3, np.int32)
    empty = np.zeros(0, np.int32)
    batch = [pats[0], bogus, pats[1], empty]
    got = svc.list_docs(batch, max_df=32, max_buf=MAX_BUF)
    ref = svc.list_docs(batch, max_df=32, engine="reference", max_buf=MAX_BUF)
    assert got == ref
    assert got[1] == [] and got[3] == []
    assert svc.topk(batch, k=3, max_buf=MAX_BUF)[1] == []
    assert int(svc.count(batch)[1]) == 0 and int(svc.count(batch)[3]) == 0


def test_search_kernel_path_parity():
    """The fused Pallas backward-search path (use_search_kernel=True,
    interpret mode on CPU) must be bit-identical to engine="reference",
    including missing patterns (out-of-alphabet symbol) and empty rows."""
    coll = generate(SPECS["version"])
    svc = RetrievalService.build(
        coll, block_size=16, beta=8.0, use_search_kernel=True
    )
    assert svc.use_search_kernel
    pats = random_substring_patterns(coll, 60, 5, 24)
    bogus = np.full(6, coll.sigma + 3, np.int32)
    batch = pats[:12] + [bogus, np.zeros(0, np.int32)]

    got = svc.list_docs(batch, max_df=64, max_buf=MAX_BUF)
    ref = svc.list_docs(batch, max_df=64, engine="reference", max_buf=MAX_BUF)
    assert got == ref
    assert got[-2] == [] and got[-1] == []

    assert svc.topk(batch, k=5, max_buf=MAX_BUF) == svc.topk(
        batch, k=5, engine="reference", max_buf=MAX_BUF
    )
    assert np.array_equal(svc.count(batch), svc.count_ilcp(batch))

    # plan parity against a kernel-free service over the same collection
    plain = RetrievalService.build(
        coll, block_size=16, beta=8.0, use_search_kernel=False
    )
    pk, pf = svc.plan(batch), plain.plan(batch)
    for name in ("lo", "hi", "occ", "df", "engine"):
        assert np.array_equal(pk[name], pf[name]), name


def test_plan_engine_assignment(svc_pats):
    svc, pats = svc_pats
    plan = svc.plan(pats[:12])
    assert set(plan["engine"]).issubset(
        {ENGINE_EMPTY, ENGINE_BRUTE, ENGINE_ILCP, ENGINE_PDL}
    )
    nonempty = plan["occ"] > 0
    # auto never assigns ILCP (the paper's recommendation is brute-vs-PDL)
    assert np.all(np.isin(plan["engine"][nonempty], [ENGINE_BRUTE, ENGINE_PDL]))
    forced = svc.plan(pats[:12], engine="ilcp")
    assert np.all(forced["engine"][nonempty] == ENGINE_ILCP)
    # the policy itself: occ < threshold * df -> brute
    occ, df = plan["occ"][nonempty], np.maximum(plan["df"][nonempty], 1)
    want = np.where(occ < svc.occ_df_threshold * df, ENGINE_BRUTE, ENGINE_PDL)
    assert np.array_equal(plan["engine"][nonempty], want)


def test_count_matches_truth(svc_pats):
    svc, pats = svc_pats
    from repro.core.suffix import build_suffix_data, sa_range_for_pattern

    data = build_suffix_data(svc.coll)
    got = svc.count(pats[:12])
    for i, p in enumerate(pats[:12]):
        lo, hi = sa_range_for_pattern(data, p)
        assert int(got[i]) == len(set(data.da[lo:hi].tolist()))


# ---------------------------------------------------------------------------
# Shape-bucketing compile cache
# ---------------------------------------------------------------------------


def test_one_compile_per_bucket():
    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=5, base_len=80,
                      mutation_rate=0.01, seed=11)
    )
    # brute_window pinned: the dispatch-aware auto window is allowed its own
    # (bounded) recompiles and has a dedicated test below
    svc = RetrievalService.build(
        coll, block_size=16, beta=8.0, brute_window=MAX_BUF
    )
    pats = random_substring_patterns(coll, 200, 5, 16)
    assert len(pats) >= 9

    compile_events = []
    recording = []
    jax.monitoring.register_event_listener(
        lambda name, **kw: compile_events.append(name)
        if recording and "compile" in name
        else None
    )

    # batch sizes 5 and 7 land in the same power-of-two bucket (8)
    svc.list_docs(pats[:5], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 1

    recording.append(True)  # arm the listener: bucket is warm now
    out7 = svc.list_docs(pats[:7], max_df=32, max_buf=MAX_BUF)
    out5 = svc.list_docs(pats[:5], max_df=32, engine="pdl", max_buf=MAX_BUF)
    recording.clear()

    assert svc.compile_counts["list"] == 1, "same bucket must not recompile"
    assert not compile_events, f"hot path triggered XLA compiles: {compile_events}"
    assert len(out7) == 7 and len(out5) == 5

    # a new bucket (16) compiles exactly once more
    svc.list_docs(pats[:9], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2
    svc.list_docs(pats[:16], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2

    # engine mode is traced, not static: no recompile across engines
    for engine in ("auto", "brute", "ilcp", "pdl"):
        svc.list_docs(pats[:7], max_df=32, engine=engine, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == 2

    # other endpoints keep their own per-bucket tally
    svc.topk(pats[:5], k=3, max_buf=MAX_BUF)
    svc.topk(pats[:8], k=3, max_buf=MAX_BUF)
    assert svc.compile_counts["topk"] == 1
    svc.tfidf([[pats[0], pats[1]]], k=3, max_buf=MAX_BUF)
    svc.tfidf([[pats[2]]], k=3, max_buf=MAX_BUF)
    assert svc.compile_counts["tfidf"] == 1


def test_auto_brute_window():
    """Dispatch-aware Brute-L window: sized per compile bucket from planner
    occ stats, power-of-two, clamped to [floor, max_buf], grow-only — and
    results stay bit-identical to the reference path."""
    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=5, base_len=80,
                      mutation_rate=0.01, seed=11)
    )
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    assert svc.brute_window is None
    pats = random_substring_patterns(coll, 100, 4, 12)
    assert len(pats) >= 9

    got = svc.list_docs(pats[:8], max_df=32, max_buf=MAX_BUF)
    ref = svc.list_docs(pats[:8], max_df=32, engine="reference",
                        max_buf=MAX_BUF)
    assert got == ref
    wins = list(svc._brute_windows.values())
    assert wins, "auto window was never recorded"
    assert all(w & (w - 1) == 0 for w in wins), "windows must be powers of 2"
    assert all(BRUTE_WINDOW_FLOOR <= w <= MAX_BUF for w in wins)

    # grow-only per bucket: a lighter batch in the same bucket never shrinks
    # the window (so it never recompiles downward)
    before = dict(svc._brute_windows)
    compiles = svc.compile_counts.get("list", 0)
    svc.list_docs(pats[1:9], max_df=32, max_buf=MAX_BUF)
    for key, win in before.items():
        assert svc._brute_windows[key] >= win
    assert svc.compile_counts["list"] <= compiles + 1

    # forcing brute routes every nonempty query through the sized window;
    # parity with the reference loop proves the window never truncates
    gb = svc.list_docs(pats[:8], max_df=32, engine="brute", max_buf=MAX_BUF)
    rb = svc.list_docs(pats[:8], max_df=32, engine="reference:brute",
                       max_buf=MAX_BUF)
    assert gb == rb
    gt = svc.topk(pats[:8], k=4, engine="brute", max_buf=MAX_BUF)
    rt = svc.topk(pats[:8], k=4, engine="reference:brute", max_buf=MAX_BUF)
    assert gt == rt


def test_empty_batch():
    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=4, base_len=60,
                      mutation_rate=0.01, seed=3)
    )
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    assert svc.list_docs([]) == []
    assert svc.topk([]) == []
    assert svc.tfidf([]) == []
    assert svc.count([]).shape == (0,)


def test_list_kernel_service_parity_oob_and_compile():
    """A ``use_list_kernel=True`` service answers bit-identically to the
    reference path — including patterns with out-of-alphabet symbols,
    which must stay empty through the fused listing kernel — and keeps
    the one-compile-per-bucket discipline."""
    import numpy as np

    coll = generate(SPECS["version"])
    svc = RetrievalService.build(
        coll, block_size=16, beta=8.0, brute_window=MAX_BUF,
        use_list_kernel=True,
    )
    assert svc.use_list_kernel is True
    pats = random_substring_patterns(coll, 200, 5, 12)
    pats_oob = pats[:6] + [np.asarray([coll.sigma + 3, 1, 2], np.int32)]
    for eng in ("auto", "ilcp", "brute", "pdl"):
        got = svc.list_docs(pats_oob, max_df=32, engine=eng, max_buf=MAX_BUF)
        want = svc.list_docs(
            pats_oob, max_df=32, max_buf=MAX_BUF,
            engine="reference" if eng == "auto" else f"reference:{eng}",
        )
        assert got == want, eng
        assert got[-1] == [], "OOB symbol must produce an empty answer"

    before = svc.compile_counts["list"]
    svc.list_docs(pats[:5], max_df=32, max_buf=MAX_BUF)
    svc.list_docs(pats[:7], max_df=32, max_buf=MAX_BUF)
    assert svc.compile_counts["list"] == before, \
        "same bucket must not recompile on the listing-kernel backend"
