"""Docs-mesh sharded serving: cross-shard merge parity + kernel restoration.

Every endpoint of ``ShardedRetrievalService`` must be bit-identical to the
single-device reference oracle — the merges (psum counting, offset+sort
listing, (tf desc, id asc) top-k, global-df tf-idf scoring) are exact
algebra over document-disjoint shards, not approximations.  The suite also
proves the tentpole perf claim: an index whose wavelet matrix is over the
fused kernel's VMEM budget (and therefore falls back to the XLA pair
descent unsharded) serves through the Pallas kernel again once sharded,
one launch per shard.

Host devices are virtualized by conftest (XLA_FLAGS
``--xla_force_host_platform_device_count=8``), so the docs mesh is real:
the merge stages run as shard_map programs over 4 devices, not a
single-device simulation.
"""

import numpy as np
import pytest

import jax

from repro.core.suffix import concat_documents
from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.dist.sharding import doc_shard_bounds, make_docs_mesh
from repro.errors import IndexIntegrityError
from repro.kernels import ops
from repro.serve import faults
from repro.serve.faults import FaultSpec
from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import RuntimeConfig, ServeRuntime
from repro.serve.sharded import ShardedRetrievalService

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="docs-mesh tests need >= 4 (virtual) devices",
)

N_SHARDS = 4
GENEROUS = 300.0


def _resident_bytes(csa):
    return ops.backward_search_resident_bytes(
        csa.wm.words, csa.wm.ones_prefix, csa.wm.zcount,
        csa.counts[: csa.sigma] - csa.wm.sym_starts,
    )


@pytest.fixture(scope="module")
def setup():
    coll = generate(SyntheticSpec(
        "version", n_base=3, n_variants=7, base_len=90,
        mutation_rate=0.01, seed=5,
    ))
    base = RetrievalService.build(coll, block_size=16, beta=8.0,
                                  validate=False)
    mesh = make_docs_mesh(N_SHARDS)
    # mesh= routes RetrievalService.build through the sharded builder;
    # validate=True covers the shard-keyed fingerprint path
    svc = RetrievalService.build(coll, mesh=mesh, block_size=16, beta=8.0,
                                 validate=True)
    assert isinstance(svc, ShardedRetrievalService)
    pats = random_substring_patterns(coll, 24, 3, 14)
    assert pats
    return coll, base, svc, pats


# ---------------------------------------------------------------------------
# Parity: every endpoint bit-identical to the single-device oracle
# ---------------------------------------------------------------------------
# non-truncating regime: max_df covers every document, buffers cover every
# occurrence, so sharded/unsharded differ only if the merge algebra is wrong


def _maxdf(coll):
    return coll.d + 1


def test_count_parity(setup):
    coll, base, svc, pats = setup
    got = svc.count(pats)
    want = np.asarray(base.count(pats, engine="reference"))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(svc.count(pats, engine="reference"), want)


def test_list_parity(setup):
    coll, base, svc, pats = setup
    want = base.list_docs(pats, max_df=_maxdf(coll), engine="reference",
                          max_buf=4096)
    assert svc.list_docs(pats, max_df=_maxdf(coll), max_buf=4096) == want
    assert svc.list_docs(pats, max_df=_maxdf(coll), engine="reference",
                         max_buf=4096) == want


def test_topk_parity(setup):
    coll, base, svc, pats = setup
    for k in (1, 3, coll.d):
        want = base.topk(pats, k=k, engine="reference", max_buf=4096)
        assert svc.topk(pats, k=k, max_buf=4096) == want
        assert svc.topk(pats, k=k, engine="reference", max_buf=4096) == want


@pytest.mark.parametrize("conjunctive", [False, True])
def test_tfidf_parity_exact_floats(setup, conjunctive):
    coll, base, svc, pats = setup
    queries = [pats[i:i + 2] for i in range(0, 12, 2)]
    want = base.tfidf(queries, k=coll.d, conjunctive=conjunctive,
                      max_buf=4096, engine="reference")
    got = svc.tfidf(queries, k=coll.d, conjunctive=conjunctive, max_buf=4096)
    # exact float equality: per-document scores are computed with the
    # global df/N weights inside the owning shard, so no reassociation
    assert got == want
    assert svc.tfidf(queries, k=coll.d, conjunctive=conjunctive,
                     max_buf=4096, engine="reference") == want


def test_plan_merges_global_occ_df(setup):
    coll, base, svc, pats = setup
    plan = svc.plan(pats)
    want = base.plan(pats)
    assert plan["lo"].shape == (N_SHARDS, len(pats))
    np.testing.assert_array_equal(plan["occ"], want["occ"])
    np.testing.assert_array_equal(plan["df"], want["df"])
    # shard-local occ sums to the global count
    np.testing.assert_array_equal(
        (plan["hi"] - plan["lo"]).sum(axis=0), plan["occ"]
    )


# ---------------------------------------------------------------------------
# Degenerate shards
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def skewed():
    """8 tiny documents built so shard behaviour is adversarial under a
    4-way split (bounds (0,2)(2,4)(4,6)(6,8)):

    * ``common`` occurs once in every document — df = 8 exceeds any single
      shard's document count, so top-k with k = 8 must merge across all
      shards;
    * ``only0`` occurs only in document 0 — every other shard contributes
      an empty answer to the merge;
    * ``absent`` occurs nowhere — every shard's answer is empty.
    """
    docs = [[1, 2, 3] + [4] * (i + 1) for i in range(8)]
    docs[0] = [1, 2, 3, 7, 7, 7]
    coll = concat_documents(docs)
    base = RetrievalService.build(coll, block_size=8, beta=4.0,
                                  validate=False)
    svc = ShardedRetrievalService.build(
        coll, make_docs_mesh(N_SHARDS), block_size=8, beta=4.0,
    )
    text = np.asarray(coll.text)
    common = text[0:3]                       # [1,2,3] shifted
    only0 = text[3:5]                        # [7,7] shifted, doc 0 only
    absent = np.asarray([text[3], text[0], text[3]])  # 7,1,7: nowhere
    return coll, base, svc, common, only0, absent


def test_all_hits_in_one_shard(skewed):
    coll, base, svc, common, only0, absent = skewed
    want = base.list_docs([only0], max_df=_maxdf(coll), engine="reference",
                          max_buf=1024)
    got = svc.list_docs([only0], max_df=_maxdf(coll), max_buf=1024)
    assert got == want
    lo, hi = svc.shard_doc_range(0)
    assert got[0] and all(lo <= d < hi for d in got[0])


def test_empty_answer_every_shard(skewed):
    coll, base, svc, common, only0, absent = skewed
    assert int(svc.count([absent])[0]) == 0
    assert svc.list_docs([absent], max_df=_maxdf(coll), max_buf=1024) == [[]]
    assert svc.topk([absent], k=4, max_buf=1024) == [[]]


def test_k_exceeds_any_single_shards_hits(skewed):
    coll, base, svc, common, only0, absent = skewed
    k = coll.d  # every shard holds only 2 documents
    want = base.topk([common, only0], k=k, engine="reference", max_buf=1024)
    got = svc.topk([common, only0], k=k, max_buf=1024)
    assert got == want
    assert len(got[0]) == coll.d  # the union spans all shards


def test_more_shards_than_documents_rejected():
    coll = concat_documents([[1, 2], [2, 1]])
    with pytest.raises(ValueError):
        doc_shard_bounds(coll.d, 4)


# ---------------------------------------------------------------------------
# validate=True over a sharded index pytree
# ---------------------------------------------------------------------------


def test_validate_populates_per_shard_fingerprints(setup):
    coll, base, svc, pats = setup
    for s in range(svc.n_shards):
        assert any(k.startswith(f"shard{s}:") for k in svc.fingerprints)
    # partition bookkeeping covers the whole collection
    assert sum(sh.coll.d for sh in svc.shards) == coll.d


def test_validate_rejects_tampered_shard(skewed):
    coll, *_ = skewed
    from repro.serve.validate import validate_sharded_service

    svc = ShardedRetrievalService.build(
        coll, make_docs_mesh(N_SHARDS), block_size=8, beta=4.0,
        validate=False,
    )
    svc.shards[1].da = np.full_like(np.asarray(svc.shards[1].da), coll.d + 9)
    with pytest.raises(IndexIntegrityError):
        validate_sharded_service(svc)


def test_validate_rejects_bad_partition(skewed):
    coll, *_ = skewed
    from repro.serve.validate import validate_sharded_service

    svc = ShardedRetrievalService.build(
        coll, make_docs_mesh(N_SHARDS), block_size=8, beta=4.0,
        validate=False,
    )
    svc.doc_bases = np.asarray([0, 2, 4, 7], np.int32)  # misaligned split
    with pytest.raises(IndexIntegrityError):
        validate_sharded_service(svc)


# ---------------------------------------------------------------------------
# Tentpole: kernel path restored for an over-budget index
# ---------------------------------------------------------------------------


def test_kernel_restored_when_sharded(setup, monkeypatch):
    """With the VMEM budget pinched between the per-shard and the global
    wavelet-matrix footprint, the unsharded program falls back to the XLA
    pair descent (zero pallas_calls) while the sharded program launches the
    fused kernel once per shard — and still answers bit-identically."""
    coll, base, svc, pats = setup
    from repro.analysis.jaxpr import count_primitive

    global_bytes = _resident_bytes(base.csa)
    shard_bytes = max(_resident_bytes(sh.csa) for sh in svc.shards)
    assert shard_bytes < global_bytes
    budget = (shard_bytes + global_bytes) // 2
    monkeypatch.setattr(ops, "BACKWARD_SEARCH_VMEM_BUDGET", budget)

    unsharded = base.trace_endpoint("plan", use_kernel=True)
    assert count_primitive(unsharded, "pallas_call") == 0  # over budget
    sharded = svc.trace_endpoint("plan", use_kernel=True)
    assert count_primitive(sharded, "pallas_call") == svc.n_shards

    # end to end through the kernel (interpret mode off-TPU): same answers
    svc_k = ShardedRetrievalService.build(
        coll, svc.mesh, block_size=16, beta=8.0,
        use_search_kernel=True, validate=False,
    )
    few = pats[:4]
    want = base.list_docs(few, max_df=_maxdf(coll), engine="reference",
                          max_buf=4096)
    assert svc_k.list_docs(few, max_df=_maxdf(coll), max_buf=4096) == want
    np.testing.assert_array_equal(
        svc_k.count(few), np.asarray(base.count(few, engine="reference"))
    )


# ---------------------------------------------------------------------------
# Compile discipline: one program per endpoint x shape bucket
# ---------------------------------------------------------------------------


def test_one_compile_per_endpoint_bucket(setup):
    coll, base, svc, pats = setup
    before = dict(svc.compile_counts)
    # same shape bucket every time -> the cache must not recompile
    for _ in range(3):
        svc.list_docs(pats, max_df=_maxdf(coll), max_buf=4096)
        svc.topk(pats, k=3, max_buf=4096)
        svc.count(pats)
    assert svc.compile_counts == before
    # a new batch bucket is exactly one more lowering of that endpoint
    svc.list_docs(pats[:2], max_df=_maxdf(coll), max_buf=4096)
    assert svc.compile_counts["list"] == before["list"] + 1


# ---------------------------------------------------------------------------
# ServeRuntime rides the sharded service unchanged
# ---------------------------------------------------------------------------


def test_runtime_over_sharded_service(setup):
    coll, base, svc, pats = setup
    rt = ServeRuntime(svc, RuntimeConfig(
        default_deadline_s=GENEROUS, backoff_base_s=0.0,
    ))
    answers = rt.serve([
        ("list", pats[0]), ("count", pats[1]),
        ("topk", pats[2]), ("tfidf", pats[3:5]),
    ])
    assert not any(a.degraded for a in answers)
    assert answers[0].result == svc.list_docs(
        [pats[0]], max_df=rt.config.max_df, engine="reference",
        max_buf=rt.config.max_buf,
    )[0]
    assert answers[1].result == int(svc.count([pats[1]],
                                              engine="reference")[0])


def test_runtime_fault_injection_degrades_to_sharded_reference(setup):
    coll, base, svc, pats = setup
    rt = ServeRuntime(svc, RuntimeConfig(
        default_deadline_s=GENEROUS, backoff_base_s=0.0, max_retries=1,
    ))
    ref = svc.list_docs(pats[:3], max_df=rt.config.max_df,
                        engine="reference", max_buf=rt.config.max_buf)
    with faults.inject(FaultSpec("executor", "error", rate=1.0)):
        answers = rt.serve([("list", p) for p in pats[:3]])
    assert all(a.degraded for a in answers)
    assert [a.result for a in answers] == ref


def test_list_kernel_restored_when_sharded(setup, monkeypatch):
    """Listing-kernel counterpart of the restoration contract: with the
    listing VMEM budget pinched between the per-shard and the global
    footprint (resident tables + tiles + scratch), the unsharded list
    program loses its listing launch while the sharded program keeps one
    fused listing launch per shard — and both kernels together make the
    per-shard launch count 2S."""
    coll, base, svc, pats = setup
    from repro.analysis.jaxpr import count_primitive

    def list_bytes(s):
        return ops.block_meta_bytes(ops.ilcp_list_block_meta(
            s.ilcp.vilcp, s.ilcp.rmq.table, s.ilcp.run_starts, s.da,
            batch=8, d=s.ilcp.d, max_df=64,
        ))

    global_bytes = list_bytes(base)
    shard_bytes = max(list_bytes(sh) for sh in svc.shards)
    assert shard_bytes < global_bytes
    budget = (shard_bytes + global_bytes) // 2
    monkeypatch.setattr(ops, "ILCP_LIST_VMEM_BUDGET", budget)

    unsharded = base.trace_endpoint(
        "list", use_kernel=False, use_list_kernel=True
    )
    assert count_primitive(unsharded, "pallas_call") == 0  # over budget
    sharded = svc.trace_endpoint(
        "list", use_kernel=False, use_list_kernel=True
    )
    assert count_primitive(sharded, "pallas_call") == svc.n_shards
    both = svc.trace_endpoint("list", use_kernel=True, use_list_kernel=True)
    assert count_primitive(both, "pallas_call") == 2 * svc.n_shards

    # end to end through both kernels: same answers as the reference
    svc_k = ShardedRetrievalService.build(
        coll, svc.mesh, block_size=16, beta=8.0,
        use_search_kernel=True, use_list_kernel=True, validate=False,
    )
    few = pats[:4]
    want = base.list_docs(few, max_df=_maxdf(coll), engine="reference",
                          max_buf=4096)
    assert svc_k.list_docs(few, max_df=_maxdf(coll), max_buf=4096) == want
