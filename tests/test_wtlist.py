"""WT document lister vs oracle (distinct docs AND frequencies)."""

from collections import Counter

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.wtlist import build_da_wavelet, wt_list_docs, wt_topk

RNG = np.random.default_rng(71)


@pytest.fixture(scope="module")
def fixture():
    base = "".join(RNG.choice(list("acgt"), 60))
    docs = []
    for _ in range(9):
        b = list(base)
        for _ in range(4):
            b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
        docs.append("".join(b))
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    wm = build_da_wavelet(data.da, coll.d)
    return docs, coll, data, wm


def test_wt_listing_matches_oracle(fixture):
    docs, coll, data, wm = fixture
    pats = {d[i : i + m] for d in docs for m in (1, 2, 3) for i in range(0, 40, 3)}
    for p in sorted(pats):
        lo, hi = sa_range_for_pattern(data, encode_pattern(p))
        if lo >= hi:
            continue
        got_docs, got_freqs, cnt = wt_list_docs(wm, lo, hi, coll.d + 1)
        got = {
            int(a): int(b)
            for a, b in zip(np.asarray(got_docs)[: int(cnt)],
                            np.asarray(got_freqs)[: int(cnt)])
        }
        exp = dict(Counter(data.da[lo:hi].tolist()))
        assert got == exp, p


def test_wt_docs_sorted_ascending(fixture):
    docs, coll, data, wm = fixture
    lo, hi = 0, coll.n
    got_docs, _, cnt = wt_list_docs(wm, lo, hi, coll.d + 1)
    ds = np.asarray(got_docs)[: int(cnt)]
    assert (np.diff(ds) > 0).all()  # left-first traversal emits sorted ids


def test_wt_topk(fixture):
    docs, coll, data, wm = fixture
    for p in ["a", "ac", "cg"]:
        lo, hi = sa_range_for_pattern(data, encode_pattern(p))
        if lo >= hi:
            continue
        topd, topf = wt_topk(wm, lo, hi, 4, coll.d + 1)
        exp = sorted(Counter(data.da[lo:hi].tolist()).items(),
                     key=lambda kv: (-kv[1], kv[0]))[:4]
        got = [(int(a), int(b)) for a, b in zip(np.asarray(topd), np.asarray(topf))
               if a >= 0]
        assert got == exp, p


@settings(max_examples=15, deadline=None)
@given(st.lists(st.text(alphabet="ab", min_size=1, max_size=10), min_size=2,
                max_size=6), st.data())
def test_wt_property(docs, data_strat):
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    wm = build_da_wavelet(data.da, coll.d)
    lo = data_strat.draw(st.integers(0, coll.n - 1))
    hi = data_strat.draw(st.integers(lo + 1, coll.n))
    got_docs, got_freqs, cnt = wt_list_docs(wm, lo, hi, coll.d + 1)
    got = {
        int(a): int(b)
        for a, b in zip(np.asarray(got_docs)[: int(cnt)],
                        np.asarray(got_freqs)[: int(cnt)])
    }
    assert got == dict(Counter(data.da[lo:hi].tolist()))
