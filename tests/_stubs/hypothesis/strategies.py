"""Strategy objects for the hypothesis shim: seeded random example drawing.

Each strategy exposes ``example(rng)``; composite strategies recurse.  The
``data()`` strategy mirrors hypothesis' interactive draws by handing the
test a ``DataObject`` bound to the per-example RNG.
"""

from __future__ import annotations

import string


class SearchStrategy:
    def __init__(self, draw_fn, name="strategy"):
        self._draw = draw_fn
        self._name = name

    def example(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)), f"{self._name}.map")

    def filter(self, pred, max_tries: int = 100):
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise AssertionError(f"filter on {self._name} found no example")

        return SearchStrategy(draw, f"{self._name}.filter")

    def __repr__(self):
        return self._name


def integers(min_value: int = -(2**31), max_value: int = 2**31 - 1):
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(elements):
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))], "sampled_from")


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int | None = None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        size = rng.randint(min_size, hi)
        return [elements.example(rng) for _ in range(size)]

    return SearchStrategy(draw, f"lists(..., {min_size}, {hi})")


def text(
    alphabet: str = string.ascii_lowercase, min_size: int = 0,
    max_size: int | None = None,
):
    chars = list(alphabet)
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        size = rng.randint(min_size, hi)
        return "".join(chars[rng.randrange(len(chars))] for _ in range(size))

    return SearchStrategy(draw, f"text({min_size}, {hi})")


class DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.example(self._rng)


def data():
    return SearchStrategy(lambda rng: DataObject(rng), "data()")
