"""Minimal ``hypothesis`` shim for environments without the real package.

Implements exactly the surface the test-suite uses — ``given``, ``settings``,
``strategies.{integers,lists,text,sampled_from,booleans,data}`` — as a
seeded randomized-example runner.  Examples are drawn from a deterministic
per-test RNG, so runs are reproducible; there is no shrinking or database.
tests/conftest.py only puts this package on sys.path when the real
hypothesis is not installed.
"""

from __future__ import annotations

import functools
import inspect
import random

from . import strategies

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        # positional strategies fill the LAST parameters (hypothesis
        # semantics), so bind them by name — fixtures keep the front slots
        fn_params = [p.name for p in inspect.signature(fn).parameters.values()]
        strat_names = fn_params[len(fn_params) - len(strats):] if strats else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"hyp:{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                drawn = {k: s.example(rng) for k, s in zip(strat_names, strats)}
                drawn.update((k, s.example(rng)) for k, s in kwstrats.items())
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # noqa: BLE001 - re-raise with example
                    raise AssertionError(
                        f"falsifying example (#{i}): {drawn!r}"
                    ) from e
            return None

        # hide the strategy-filled parameters from pytest's fixture
        # resolution: positional strategies fill the *last* len(strats)
        # parameters, keyword strategies fill by name
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if strats:
            params = params[: len(params) - len(strats)]
        params = [p for p in params if p.name not in kwstrats]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__  # keep pytest off the original signature
        return wrapper

    return deco
