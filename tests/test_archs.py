"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness (assignment deliverable f).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — validated in test_dryrun_cells.py / launch.dryrun.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ALL_ARCHS, get_arch_module
from repro.models import nequip as nequip_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

RNG = np.random.default_rng(61)
KEY = jax.random.PRNGKey(0)

LM_ARCHS = [a for a in ALL_ARCHS if get_arch_module(a).FAMILY == "lm"]
RECSYS_ARCHS = [a for a in ALL_ARCHS if get_arch_module(a).FAMILY == "recsys"]


def _finite(tree):
    return all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    cfg = get_arch_module(arch).reduced_config()
    params = tf_mod.init_params(cfg, KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    # train step (loss + grads + optimizer update)
    opt_cfg = AdamWConfig()
    opt = adamw_init(params, opt_cfg)
    loss, grads = jax.value_and_grad(
        lambda p: tf_mod.forward_train(cfg, p, tokens, tokens)
    )(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(grads)
    new_params, new_opt = adamw_update(opt_cfg, params, grads, opt)
    assert _finite(new_params)
    assert int(new_opt["step"]) == 1

    # prefill + one decode step
    logits, cache = tf_mod.forward_prefill(cfg, params, tokens)
    assert logits.shape == (B, cfg.vocab)
    dc = tf_mod.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    dl, dc = tf_mod.forward_decode(cfg, params, tokens[:, 0], dc, 0)
    assert dl.shape == (B, cfg.vocab)
    assert _finite(dl)


def test_nequip_arch_smoke():
    cfg = get_arch_module("nequip").reduced_config()
    params = nequip_mod.init_params(cfg, KEY)
    N, E, G = 24, 48, 3
    batch = {
        "node_feat": jnp.asarray(RNG.standard_normal((N, cfg.d_feat_in)), jnp.float32),
        "edge_index": jnp.asarray(RNG.integers(0, N, (2, E)), jnp.int32),
        "edge_vec": jnp.asarray(RNG.standard_normal((E, 3)) * 2, jnp.float32),
        "graph_id": jnp.asarray(np.sort(RNG.integers(0, G, N)), jnp.int32),
        "energy": jnp.zeros(G, jnp.float32),
    }
    e = nequip_mod.forward_energy(
        cfg, params, batch["node_feat"], batch["edge_index"], batch["edge_vec"],
        batch["graph_id"], G,
    )
    assert e.shape == (G,)
    assert _finite(e)
    loss, grads = jax.value_and_grad(
        lambda p: nequip_mod.forward_train(cfg, p, batch, G)
    )(params)
    assert np.isfinite(float(loss))
    assert _finite(grads)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_arch_smoke(arch):
    cfg = get_arch_module(arch).reduced_config()
    B = 16
    if arch == "sasrec":
        params = recsys_mod.sasrec_init(cfg, KEY)
        batch = {
            "item_seq": jnp.asarray(RNG.integers(0, cfg.n_items, (B, cfg.seq_len)), jnp.int32),
            "pos_items": jnp.asarray(RNG.integers(1, cfg.n_items, (B, cfg.seq_len)), jnp.int32),
            "neg_items": jnp.asarray(RNG.integers(1, cfg.n_items, (B, cfg.seq_len)), jnp.int32),
        }
        loss_fn = lambda p: recsys_mod.sasrec_train_loss(cfg, p, batch)
        retr = recsys_mod.sasrec_retrieval(
            cfg, params, batch["item_seq"][:1], jnp.arange(32, dtype=jnp.int32)
        )
        assert retr.shape == (32,)
    else:
        init, losses, retrs = {
            "fm": (recsys_mod.fm_init, recsys_mod.fm_train_loss, recsys_mod.fm_retrieval),
            "autoint": (recsys_mod.autoint_init, recsys_mod.autoint_train_loss,
                        recsys_mod.autoint_retrieval),
            "dlrm-mlperf": (recsys_mod.dlrm_init, recsys_mod.dlrm_train_loss,
                            recsys_mod.dlrm_retrieval),
        }[arch]
        params = init(cfg, KEY)
        batch = {
            "sparse": jnp.asarray(
                RNG.integers(0, min(cfg.vocab_sizes), (B, cfg.n_sparse)), jnp.int32
            ),
            "label": jnp.asarray(RNG.integers(0, 2, B), jnp.float32),
        }
        if arch == "dlrm-mlperf":
            batch["dense"] = jnp.asarray(RNG.standard_normal((B, cfg.n_dense)), jnp.float32)
        loss_fn = lambda p: losses(cfg, p, batch)
        cand = jnp.arange(32, dtype=jnp.int32)
        if arch == "dlrm-mlperf":
            r = retrs(cfg, params, batch["dense"][0], batch["sparse"][0], cand)
        else:
            r = retrs(cfg, params, batch["sparse"][0], cand)
        assert r.shape == (32,)
        assert _finite(r)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(grads)
    opt_cfg = AdamWConfig()
    new_params, _ = adamw_update(opt_cfg, params, grads, adamw_init(params, opt_cfg))
    assert _finite(new_params)


def test_all_archs_have_configs_and_shapes():
    from repro.configs.registry import ARCH_SHAPES

    assert len(ALL_ARCHS) == 10
    total_cells = sum(len(v) for v in ARCH_SHAPES.values())
    assert total_cells == 40
    for arch in ALL_ARCHS:
        mod = get_arch_module(arch)
        assert mod.ARCH_ID == arch
        assert callable(mod.config) and callable(mod.reduced_config)


def test_exact_assigned_constants():
    """The full configs must match the assigned-architecture table."""
    c = get_arch_module("llama4-scout-17b-a16e").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        48, 5120, 40, 8, 8192, 202048)
    assert c.moe.n_experts == 16 and c.moe.top_k == 1
    c = get_arch_module("llama4-maverick-400b-a17b").config()
    assert c.moe.n_experts == 128
    c = get_arch_module("llama3.2-3b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        28, 3072, 24, 8, 8192, 128256)
    c = get_arch_module("smollm-135m").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        30, 576, 9, 3, 1536, 49152)
    c = get_arch_module("mistral-large-123b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        88, 12288, 96, 8, 28672, 32768)
    c = get_arch_module("nequip").config()
    assert (c.n_layers, c.channels, c.l_max, c.n_rbf, c.cutoff) == (5, 32, 2, 8, 5.0)
    c = get_arch_module("fm").config()
    assert (c.n_sparse, c.embed_dim) == (39, 10)
    c = get_arch_module("sasrec").config()
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (50, 2, 1, 50)
    c = get_arch_module("autoint").config()
    assert (c.n_sparse, c.embed_dim, c.n_attn_layers, c.n_heads, c.d_attn) == (
        39, 16, 3, 2, 32)
    c = get_arch_module("dlrm-mlperf").config()
    assert (c.n_dense, c.n_sparse, c.embed_dim) == (13, 26, 128)
    assert c.bot_mlp == (512, 256, 128) and c.top_mlp == (1024, 1024, 512, 256, 1)
