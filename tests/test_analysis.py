"""Tests for the static-analysis gate (repro.analysis).

Three layers, mirroring the subsystem:

* the jaxpr walker descends into params-nested sub-jaxprs (the gap the
  old hand-rolled ``count_eqns`` in test_kernels had);
* the contract auditor catches each seeded violation class — an extra
  pallas_call, an injected pure_callback, an f64 leak, an over-budget
  block set — and passes the real service clean;
* each AST lint rule fires on a minimal fixture snippet while the real
  tree stays clean, and the allowlist suppresses exactly what it names.
"""

import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr as jx
from repro.analysis import lint as lint_mod
from repro.analysis.contracts import (
    EndpointContract,
    audit_jaxpr,
    audit_service,
    build_registry,
    pair_descent_gather_ceiling,
    trace_for_contract,
)
from repro.data.collections import SyntheticSpec, generate
from repro.serve.retrieval import RetrievalService


@pytest.fixture(scope="module")
def svc():
    coll = generate(SyntheticSpec(
        "version", n_base=2, n_variants=4, base_len=60,
        mutation_rate=0.01, seed=7,
    ))
    return RetrievalService.build(coll, validate=False)


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------


def test_count_primitive_flat():
    jpr = jax.make_jaxpr(lambda x: jnp.sin(x) + jnp.sin(2 * x))(1.0)
    assert jx.count_primitive(jpr, "sin") == 2
    assert jx.count_primitive(jpr, "cos") == 0


def test_count_primitive_descends_into_params_jaxprs():
    # sin nested inside cond branches inside a scanned body inside jit:
    # every level stores its sub-jaxpr in eqn *params*, which is exactly
    # where the old subjaxprs-based counter could lose track.
    def branch_true(x):
        return jnp.sin(x)

    def branch_false(x):
        return jnp.sin(jnp.sin(x))

    @jax.jit
    def step(c, _):
        c = jax.lax.cond(c > 0, branch_true, branch_false, c)
        return c, c

    def prog(x):
        out, _ = jax.lax.scan(step, x, None, length=3)
        return out

    jpr = jax.make_jaxpr(prog)(1.0)
    # one sin in the true branch + two in the false branch, counted once
    # each (static program structure, not trip counts)
    assert jx.count_primitive(jpr, "sin") == 3


def test_gather_and_find_primitives():
    def prog(t, i):
        return t[i] + t[i + 1]

    jpr = jax.make_jaxpr(prog)(jnp.arange(8), 2)
    assert jx.gather_count(jpr) == jx.count_primitive(jpr, "gather")
    names = {e.primitive.name for e in jx.find_primitives(jpr, ("gather",))}
    assert names <= {"gather"}


def test_wide_dtype_eqns_flags_f64():
    with jax.experimental.enable_x64():
        jpr = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0
        )(jnp.ones((2,), jnp.float32))
    wide = jx.wide_dtype_eqns(jpr)
    assert wide and all(dt == "float64" for _, dt in wide)


def test_wide_dtype_eqns_clean_on_f32():
    jpr = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((2,), jnp.float32))
    assert jx.wide_dtype_eqns(jpr) == []


def test_find_host_callbacks():
    def prog(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((2,), jnp.float32), x
        )

    jpr = jax.make_jaxpr(prog)(jnp.ones((2,), jnp.float32))
    found = jx.find_host_callbacks(jpr)
    assert [e.primitive.name for e in found] == ["pure_callback"]


# ---------------------------------------------------------------------------
# contract auditor
# ---------------------------------------------------------------------------


def test_registry_shape(svc):
    reg = build_registry(svc, buckets=((1, 8), (8, 8)))
    # per bucket: 4 kinds x 3 backends (tfidf gained its kernel and
    # over-budget contracts alongside the sharded registry work)
    assert len(reg) == 2 * (4 * 3)
    keys = {c.key for c in reg}
    assert "plan/B8xm8/kernel" in keys
    assert "tfidf/B8xm8/xla" in keys
    assert "tfidf/B8xm8/kernel" in keys
    assert "tfidf/B8xm8/kernel_overbudget" in keys
    levels = int(svc.csa.wm.words.shape[0])
    plan = next(c for c in reg if c.key == "plan/B8xm8/kernel")
    assert plan.max_gathers == pair_descent_gather_ceiling(levels)


def test_audit_service_clean(svc):
    report, violations = audit_service(svc, buckets=((1, 8), (8, 8)))
    assert violations == []
    assert report["contracts_audited"] == len(report["endpoints"])
    assert all(e["ok"] for e in report["endpoints"])
    kernel_rows = [e for e in report["endpoints"] if e["contract"].endswith("/kernel")]
    # list programs fuse search + listing -> two launches; everything else one
    assert kernel_rows and all(
        e["pallas_calls"] == (2 if e["contract"].startswith("list/") else 1)
        for e in kernel_rows
    )
    assert any(e["contract"].startswith("list/") for e in kernel_rows)
    over_rows = [
        e for e in report["endpoints"]
        if e["contract"].endswith("/kernel_overbudget")
    ]
    # fallback proven at lowering time: budget clamped -> zero launches
    assert over_rows and all(e["pallas_calls"] == 0 for e in over_rows)


def test_audit_catches_extra_pallas_call(svc):
    contract = EndpointContract("plan", (8, 8), "kernel", pallas_calls=2)
    traced = trace_for_contract(
        svc, EndpointContract("plan", (8, 8), "kernel", pallas_calls=1)
    )
    vs = audit_jaxpr(traced, contract)
    assert [v.check for v in vs] == ["pallas_calls"]


def test_audit_catches_injected_host_callback(svc):
    fn, build_args = svc.endpoint_program("plan", use_kernel=False)

    def poisoned(*a):
        out = fn(*a)
        leaf = jax.tree.leaves(out)[0]
        leaf = jax.pure_callback(
            lambda v: np.asarray(v),
            jax.ShapeDtypeStruct(leaf.shape, leaf.dtype), leaf,
        )
        return out, leaf

    traced = jax.make_jaxpr(poisoned)(*build_args(8, 8))
    contract = EndpointContract("plan", (8, 8), "xla", pallas_calls=0)
    vs = audit_jaxpr(traced, contract)
    assert "host_callback" in {v.check for v in vs}


def test_audit_catches_f64_widening(svc):
    fn, build_args = svc.endpoint_program("plan", use_kernel=False)

    def widened(*a):
        out = fn(*a)
        leaf = jax.tree.leaves(out)[0]
        return out, leaf.astype(jnp.float64).sum()

    with jax.experimental.enable_x64():
        traced = jax.make_jaxpr(widened)(*build_args(8, 8))
    contract = EndpointContract("plan", (8, 8), "xla", pallas_calls=0)
    vs = audit_jaxpr(traced, contract)
    assert "wide_dtype" in {v.check for v in vs}


def test_audit_catches_gather_regression(svc):
    traced = trace_for_contract(
        svc, EndpointContract("plan", (8, 8), "xla", pallas_calls=0)
    )
    tight = EndpointContract("plan", (8, 8), "xla", pallas_calls=0, max_gathers=1)
    vs = audit_jaxpr(traced, tight)
    assert "gathers" in {v.check for v in vs}


def test_audit_catches_vmem_overbudget(svc):
    traced = trace_for_contract(
        svc, EndpointContract("plan", (8, 8), "kernel", pallas_calls=1)
    )
    tiny = EndpointContract(
        "plan", (8, 8), "kernel", pallas_calls=1, vmem_budget=1
    )
    vs = audit_jaxpr(traced, tiny)
    assert "vmem" in {v.check for v in vs}


def test_overbudget_contract_traces_zero_launches(svc):
    # the kernel wrapper reads the module-global budget at trace time, so
    # clamping it during the trace proves the fallback at lowering time
    contract = EndpointContract("plan", (8, 8), "kernel_overbudget", pallas_calls=0)
    traced = trace_for_contract(svc, contract)
    assert jx.count_primitive(traced, "pallas_call") == 0
    assert audit_jaxpr(traced, contract) == []


# ---------------------------------------------------------------------------
# AST lint rules — each fires on a fixture snippet, real tree stays clean
# ---------------------------------------------------------------------------


def _lint_snippet(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_mod.lint_file(path, rel)


def test_rt001_direct_clock_call(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/bad_runtime.py", """
        import time

        def tick():
            return time.monotonic()
    """)
    assert [v.rule for v in vs] == ["RT001"]
    assert "injectable" in vs[0].message + vs[0].fixit


def test_rt001_allows_injected_clock_reference(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/good_runtime.py", """
        import time

        def tick(clock=time.monotonic):
            return clock()
    """)
    assert vs == []


def test_tr001_item_and_cast_in_batch_executor(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/bad_exec.py", """
        def scores_batch(x, lens):
            n = int(lens)
            return x.sum().item() + n
    """)
    assert sorted(v.rule for v in vs) == ["TR001", "TR001"]


def test_tr001_branch_on_traced_param(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/kernels/bad_kernel.py", """
        def descend(lo, hi, words):
            if lo > 0:
                return hi
            return lo
    """)
    assert [v.rule for v in vs] == ["TR001"]


def test_tr001_static_shape_branch_is_clean(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/kernels/good_kernel.py", """
        def descend(lo, hi, words, block=None):
            if words.shape[0] > 4 and block is None:
                return hi
            return lo
    """)
    assert vs == []


def test_tr001_keyword_knob_is_clean(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/good_exec.py", """
        def scores_batch(x, lens, *, use_kernel=True):
            if use_kernel:
                return x
            return x + 1
    """)
    assert vs == []


def test_fj001_fault_site_outside_serving_module(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/core/bad_core.py", """
        from repro.serve import faults

        def lookup(x):
            faults.fire("lookup")
            return x
    """)
    assert [v.rule for v in vs] == ["FJ001"]


def test_fj001_fault_site_on_reference_path(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/retrieval.py", """
        from repro.serve import faults

        def plan_reference(x):
            faults.fire("plan")
            return x
    """)
    assert [v.rule for v in vs] == ["FJ001"]
    assert "reference" in vs[0].message


def test_fj001_direct_fault_error(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/serve/bad_site.py", """
        from repro.serve.faults import FaultInjectedError

        def go():
            raise FaultInjectedError("boom")
    """)
    assert [v.rule for v in vs] == ["FJ001"]


def test_jx001_import_time_jit_execution(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/core/bad_import.py", """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def warm(x):
            return x + 1

        _ = warm(jnp.zeros(4))
        _ = jax.jit(lambda x: x)(jnp.zeros(2))
    """)
    assert [v.rule for v in vs] == ["JX001", "JX001"]


def test_jx001_module_scope_wrapping_is_clean(tmp_path):
    vs = _lint_snippet(tmp_path, "repro/core/good_import.py", """
        import jax

        def f(x):
            return x + 1

        g = jax.jit(f)

        @jax.jit
        def h(x):
            return x - 1

        def main(x):
            return g(x) + h(x)
    """)
    assert vs == []


def test_allowlist_suppresses_named_entry(tmp_path):
    path = tmp_path / "repro/serve/noisy.py"
    path.parent.mkdir(parents=True)
    path.write_text("import time\n\ndef tick():\n    return time.time()\n")
    vs = lint_mod.lint_file(path, "repro/serve/noisy.py")
    assert vs
    allow = {"RT001": ["repro/serve/noisy.py:tick"]}
    assert all(lint_mod._allowed(v, allow) for v in vs)
    assert not any(lint_mod._allowed(v, {"RT001": ["other.py"]}) for v in vs)


def test_real_tree_is_clean():
    import pathlib

    root = pathlib.Path(lint_mod.__file__).resolve().parents[1]
    violations, stats = lint_mod.lint_tree(root)
    assert violations == [], [v.as_dict() for v in violations]
    assert stats["files_scanned"] > 30


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_lint_only_clean(tmp_path):
    from repro.analysis.report import run

    out = tmp_path / "report.json"
    assert run(["--lint-only", "--report", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert report["lint"]["violations"] == []
    assert "contracts" not in report


def test_cli_flags_dirty_tree(tmp_path):
    from repro.analysis.report import run

    bad = tmp_path / "repro/serve/bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\ndef tick():\n    return time.sleep(1)\n")
    out = tmp_path / "report.json"
    assert run(["--lint-only", "--root", str(tmp_path), "--report", str(out)]) == 1
    report = json.loads(out.read_text())
    assert report["ok"] is False
    assert [v["rule"] for v in report["lint"]["violations"]] == ["RT001"]
