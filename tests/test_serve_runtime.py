"""Resilient-runtime tests: every admitted request gets an answer.

Fault injection is deterministic (seeded schedules), the clock and sleep
are injectable, so every degradation path — retry-then-degrade, queued
deadline expiry, breaker trip + cooldown recovery — is exercised exactly,
not probabilistically.
"""

import numpy as np
import pytest

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.errors import InvalidQueryError, QueueFullError
from repro.serve import faults
from repro.serve.faults import POISON, FaultSpec, parse_fault_specs
from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import CircuitBreaker, RuntimeConfig, ServeRuntime

GENEROUS = 300.0  # deadline that a CPU test runner cannot miss


@pytest.fixture(scope="module")
def svc_pats():
    coll = generate(SyntheticSpec("version", n_base=2, n_variants=6,
                                  base_len=80, mutation_rate=0.01, seed=3))
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    pats = random_substring_patterns(coll, 40, 4, 12)
    assert pats
    return svc, pats


def _runtime(svc, **over):
    kw = dict(default_deadline_s=GENEROUS, backoff_base_s=0.0)
    kw.update(over)
    return ServeRuntime(svc, RuntimeConfig(**kw))


# ---------------------------------------------------------------------------
# Admission
# ---------------------------------------------------------------------------


def test_structurally_bad_input_rejected_at_submit(svc_pats):
    svc, _ = svc_pats
    rt = _runtime(svc)
    with pytest.raises(InvalidQueryError):
        rt.submit("list", np.ones((2, 2)))
    with pytest.raises(InvalidQueryError):
        rt.submit("frobnicate", np.ones(3, np.int32))
    with pytest.raises(InvalidQueryError):
        rt.submit("tfidf", np.ones(3, np.int32))  # terms must be a list
    assert rt.metrics.invalid == 3
    assert rt.metrics.submitted == 0


def test_soft_invalid_input_answers_empty(svc_pats):
    svc, _ = svc_pats
    rt = _runtime(svc)
    sigma = svc.coll.sigma
    answers = rt.serve([
        ("list", np.array([], dtype=np.int32)),                 # empty
        ("list", np.full(4, sigma + 5, dtype=np.int32)),        # out of alphabet
        ("count", np.full(4, sigma + 5, dtype=np.int32)),
    ])
    assert [a.result for a in answers] == [[], [], 0]
    assert not any(a.degraded for a in answers)


def test_queue_full_sheds_load(svc_pats):
    svc, pats = svc_pats
    rt = _runtime(svc, max_queue=2)
    rt.submit("count", pats[0])
    rt.submit("count", pats[1])
    with pytest.raises(QueueFullError):
        rt.submit("count", pats[2])
    assert rt.metrics.rejected == 1
    assert {a.rid for a in rt.step()} == {0, 1}


# ---------------------------------------------------------------------------
# Fault handling: retry, degrade, never an exception to the caller
# ---------------------------------------------------------------------------


def test_injected_failure_is_retried_then_succeeds(svc_pats):
    svc, pats = svc_pats
    rt = _runtime(svc, max_retries=2)
    # exactly one failure: first attempt dies, the retry runs clean
    with faults.inject(FaultSpec("executor", "error", rate=1.0, limit=1)) as inj:
        (ans,) = rt.serve([("list", pats[0])])
    assert len(inj.fired) == 1
    assert not ans.degraded and ans.path == "full"
    assert ans.retries == 1
    assert ans.result == svc.list_docs([pats[0]], engine="reference",
                                       max_df=rt.config.max_df)[0]


def test_retries_exhausted_degrades_never_raises(svc_pats):
    svc, pats = svc_pats
    rt = _runtime(svc, max_retries=1)
    ref = svc.list_docs(pats[:3], engine="reference",
                        max_df=rt.config.max_df)
    with faults.inject(FaultSpec("executor", "error", rate=1.0)):
        answers = rt.serve([("list", p) for p in pats[:3]])
    assert all(a.degraded for a in answers)
    # the floor path is also executor-backed, so the ladder lands on the
    # (uninstrumented) host reference loop — answers stay correct
    assert all(a.path == "reference" for a in answers)
    assert all(a.degrade_reason == "retries_exhausted:reference"
               for a in answers)
    assert [a.result for a in answers] == ref
    assert rt.metrics.degraded_fraction == 1.0


def test_poisoned_payload_never_reaches_caller(svc_pats):
    svc, pats = svc_pats
    rt = _runtime(svc, max_retries=0)
    with faults.inject(FaultSpec("executor", "poison", rate=1.0)):
        answers = rt.serve([("topk", pats[0])])
    (ans,) = answers
    assert ans.degraded
    for doc, _tf in ans.result:
        assert doc != int(POISON) and 0 <= doc < svc.coll.d


def test_planner_and_compile_faults_degrade(svc_pats):
    svc, pats = svc_pats
    specs = parse_fault_specs("planner_fail:1.0,compile_error:1.0")
    rt = _runtime(svc, max_retries=0)
    with faults.inject(*specs):
        answers = rt.serve([("count", pats[0]), ("list", pats[1])])
    assert all(a.degraded for a in answers)
    assert answers[0].result == int(
        svc.count([pats[0]], engine="reference")[0]
    )


def test_mixed_fault_workload_answers_everything(svc_pats):
    svc, pats = svc_pats
    specs = parse_fault_specs("executor_fail,slow_list,compile_error",
                              rate=0.2)
    rt = _runtime(svc)
    reqs = [("count" if i % 3 == 0 else "list", pats[i % len(pats)])
            for i in range(48)]
    with faults.inject(*specs, sleep=lambda s: None):
        answers = rt.serve(reqs)
    assert len(answers) == len(reqs)
    assert rt.metrics.answered == len(reqs)
    for a in answers:  # degraded or not, results respect the ABI
        if a.kind == "count":
            assert 0 <= a.result <= svc.coll.d
        else:
            assert all(0 <= d < svc.coll.d for d in a.result)


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


def test_expired_queued_requests_answer_empty_with_miss_counted(svc_pats):
    svc, pats = svc_pats
    clock = FakeClock()
    rt = ServeRuntime(svc, RuntimeConfig(backoff_base_s=0.0),
                      clock=clock, sleep=clock.sleep)
    rt.submit("count", pats[0], deadline_s=0.05)
    rt.submit("count", pats[1], deadline_s=GENEROUS)
    clock.t += 0.2          # the first request's deadline passes while queued
    answers = {a.rid: a for a in rt.step()}
    dead, live = answers[0], answers[1]
    assert dead.degraded and dead.path == "empty"
    assert dead.degrade_reason == "deadline:empty"
    assert dead.deadline_missed and dead.overrun_s > 0
    assert not live.deadline_missed
    assert live.result == int(svc.count([pats[1]], engine="reference")[0])
    assert rt.metrics.deadline_misses == 1


def test_deadline_aware_batch_shrinking(svc_pats):
    svc, pats = svc_pats
    clock = FakeClock()
    rt = ServeRuntime(svc, RuntimeConfig(max_batch=8),
                      clock=clock, sleep=clock.sleep)
    # pretend the 8-bucket is slow and the 1-bucket fast
    rt.metrics.steady_ema_s[("count", 8)] = 10.0
    rt.metrics.steady_ema_s[("count", 4)] = 10.0
    rt.metrics.steady_ema_s[("count", 2)] = 10.0
    rt.metrics.steady_ema_s[("count", 1)] = 0.001
    for p in pats[:8]:
        rt.submit("count", p, deadline_s=1.0)
    batch = rt._cut_batch(clock())
    assert len(batch) == 1          # shrunk until the estimate fits the slack
    assert batch[0].rid == 0        # earliest deadline first == FIFO here


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_state_machine_standalone():
    clock = FakeClock()
    br = CircuitBreaker(threshold=2, cooldown_s=1.0, clock=clock)
    key = ("list", 4)
    assert br.allow(key) == CircuitBreaker.CLOSED
    assert not br.record_failure(key)
    assert br.record_failure(key)            # second failure trips
    assert br.allow(key) == CircuitBreaker.OPEN
    clock.t += 1.5
    assert br.allow(key) == CircuitBreaker.HALF_OPEN
    assert br.record_failure(key)            # half-open probe fails: re-trip
    assert br.allow(key) == CircuitBreaker.OPEN
    clock.t += 3.0
    assert br.allow(key) == CircuitBreaker.HALF_OPEN
    br.record_success(key)
    assert br.allow(key) == CircuitBreaker.CLOSED
    assert br.trips == 2


def test_tripped_breaker_short_circuits_then_recovers(svc_pats):
    svc, pats = svc_pats
    clock = FakeClock()
    rt = ServeRuntime(
        svc,
        RuntimeConfig(default_deadline_s=GENEROUS, max_retries=0,
                      backoff_base_s=0.0, breaker_threshold=2,
                      breaker_cooldown_s=1.0),
        clock=clock, sleep=clock.sleep,
    )
    with faults.inject(FaultSpec("executor", "error", rate=1.0)):
        rt.serve([("list", pats[0])])        # failure 1
        rt.serve([("list", pats[1])])        # failure 2: trips the breaker
        assert rt.metrics.breaker_trips == 1
        ans = rt.serve([("list", pats[2])])[0]   # OPEN: no full-path attempt
    assert ans.degraded and ans.degrade_reason.startswith("breaker_open")
    assert rt.metrics.short_circuits == 1
    # cooldown elapses -> HALF_OPEN probe runs the (now fault-free) full path
    clock.t += 2.0
    ans = rt.serve([("list", pats[0])])[0]
    assert not ans.degraded and ans.path == "full"
    assert rt.breaker.state(("list", 1)) == CircuitBreaker.CLOSED


# ---------------------------------------------------------------------------
# Metrics / latency accounting
# ---------------------------------------------------------------------------


def test_compile_and_steady_latency_tracked_separately(svc_pats):
    svc, pats = svc_pats
    rt = _runtime(svc)
    for _ in range(3):
        rt.serve([("count", pats[0])])
    key = ("count", 1)
    assert key in rt.metrics.compile_s       # first run: compile cost
    assert key in rt.metrics.steady_ema_s    # later runs: steady EMA
    m = rt.metrics.as_dict()
    assert "count/1" in m["compile_s"] and "count/1" in m["steady_ema_s"]
    assert m["degraded_fraction"] == 0.0 and m["deadline_miss_rate"] == 0.0


def test_warmup_precompiles_buckets(svc_pats):
    svc, _ = svc_pats
    rt = _runtime(svc)
    compile_s = rt.warmup(kinds=("count",), batch_sizes=(1, 2))
    assert ("count", 1) in compile_s and ("count", 2) in compile_s
    assert rt.metrics.deadline_misses == 0
