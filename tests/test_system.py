"""End-to-end behaviour tests for the paper's system: one pass through the
whole stack — corpus generation -> index build -> every query type against
ground truth -> one training step of an assigned architecture on the same
framework substrate."""

import numpy as np
import jax
import jax.numpy as jnp


def test_end_to_end_retrieval_and_training(tmp_path):
    # 1) a repetitive corpus (the paper's regime)
    from repro.data.collections import SyntheticSpec, generate

    coll = generate(
        SyntheticSpec("version", n_base=3, n_variants=6, base_len=80,
                      mutation_rate=0.01)
    )

    # 2) the full index stack
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    rep = svc.space_report()
    assert rep["ilcp_runs"] < coll.n          # Lemma 2 regime
    assert rep["bwt_runs"] < coll.n           # RLCSA regime

    # 3) every query type against raw-document ground truth
    from collections import Counter

    from repro.core.suffix import build_suffix_data, sa_range_for_pattern

    data = build_suffix_data(coll)
    text = coll.text
    rng = np.random.default_rng(0)
    pats = []
    while len(pats) < 3:
        p = int(rng.integers(0, coll.n - 5))
        sub = text[p : p + 4]
        if (sub > 0).all():
            pats.append(np.asarray(sub, dtype=np.int32))

    dfs = svc.count(pats)
    listing = svc.list_docs(pats, max_df=coll.d + 1)
    hits = svc.topk(pats, k=3)
    for i, p in enumerate(pats):
        lo, hi = sa_range_for_pattern(data, p)
        truth = Counter(data.da[lo:hi].tolist())
        assert int(dfs[i]) == len(truth)
        assert listing[i] == sorted(truth)
        exp = sorted(truth.items(), key=lambda kv: (-kv[1], kv[0]))[:3]
        assert hits[i] == exp

    ranked = svc.tfidf([[pats[0], pats[1]]], k=3)[0]
    assert len(ranked) >= 1

    # 4) the same framework trains an assigned architecture, checkpointed
    from repro.configs.registry import get_arch_module
    from repro.models.transformer import forward_train, init_params
    from repro.train.loop import train
    from repro.train.optimizer import AdamWConfig

    cfg = get_arch_module("smollm-135m").reduced_config()
    tokens = jnp.asarray((np.asarray(text[: 4 * 64]) % cfg.vocab).reshape(4, 64))

    res = train(
        lambda params, batch: forward_train(cfg, params, batch, batch),
        lambda: init_params(cfg, jax.random.PRNGKey(0)),
        lambda step: tokens,
        n_steps=6,
        ckpt_dir=str(tmp_path),
        ckpt_every=3,
        opt_cfg=AdamWConfig(lr=1e-2, weight_decay=0.0),
    )
    assert res.final_step == 6
    assert res.losses[-1] < res.losses[0]     # overfits the fixed batch
