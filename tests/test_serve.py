"""End-to-end tests of the RetrievalService (the paper's indexes behind the
batched serving API) — all engines agree with brute-force oracles."""

import pytest

from repro.data.collections import SyntheticSpec, generate, random_substring_patterns
from repro.serve.retrieval import RetrievalService


@pytest.fixture(scope="module")
def svc_and_truth():
    coll = generate(
        SyntheticSpec("version", n_base=4, n_variants=8, base_len=120,
                      mutation_rate=0.01)
    )
    svc = RetrievalService.build(coll, block_size=16, beta=8.0)
    pats = random_substring_patterns(coll, 300, 5, 24)

    # ground truth from raw documents
    from repro.core.suffix import build_suffix_data, sa_range_for_pattern

    data = build_suffix_data(coll)
    truth = {}
    for i, p in enumerate(pats):
        lo, hi = sa_range_for_pattern(data, p)
        docs = sorted(set(data.da[lo:hi].tolist()))
        from collections import Counter

        tf = Counter(data.da[lo:hi].tolist())
        truth[i] = (docs, tf)
    return svc, pats, truth


def test_count_both_structures(svc_and_truth):
    svc, pats, truth = svc_and_truth
    sada = svc.count(pats)
    ilcp = svc.count_ilcp(pats)
    for i in range(len(pats)):
        assert int(sada[i]) == len(truth[i][0])
        assert int(ilcp[i]) == len(truth[i][0])


@pytest.mark.parametrize("engine", ["auto", "brute", "ilcp", "pdl"])
def test_listing_all_engines(svc_and_truth, engine):
    svc, pats, truth = svc_and_truth
    out = svc.list_docs(pats[:12], max_df=64, engine=engine)
    for i, docs in enumerate(out):
        assert docs == truth[i][0], (engine, i)


def test_topk_matches_truth(svc_and_truth):
    svc, pats, truth = svc_and_truth
    out = svc.topk(pats[:12], k=5)
    for i, hits in enumerate(out):
        exp = sorted(truth[i][1].items(), key=lambda kv: (-kv[1], kv[0]))[:5]
        assert hits == exp, i


def test_tfidf_service(svc_and_truth):
    svc, pats, truth = svc_and_truth
    out = svc.tfidf([[pats[0], pats[1]]], k=5)
    assert len(out) == 1 and len(out[0]) >= 1
    # scores non-increasing
    scores = [s for _, s in out[0]]
    assert all(a >= b - 1e-6 for a, b in zip(scores, scores[1:]))


def test_space_report(svc_and_truth):
    svc, pats, truth = svc_and_truth
    rep = svc.space_report()
    assert rep["bwt_runs"] < rep["n"]
    assert 0 < rep["sada_bpc"] < 8
    assert 0 < rep["ilcp_counting_bpc"] < 32
