"""Tests for Re-Pair (round-trip, compression on repetitive input) and PDL
(structure invariants, listing vs oracle, top-k vs brute oracle, both modes
and several (b, beta) settings)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.csa import build_csa
from repro.core.pdl import build_pdl, pdl_list_docs, pdl_topk
from repro.grammar.repair import (
    repair_compress,
    repair_compress_lists,
    repair_expand_host,
)

RNG = np.random.default_rng(23)


# ---------------------------------------------------------------------------
# Re-Pair
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "seq",
    [
        [0, 1, 0, 1, 0, 1, 0, 1],
        [3, 3, 3, 3, 3, 3, 3],
        [0, 1, 2, 3, 4, 5],
        [5, 4, 5, 4, 1, 5, 4, 5, 4, 1, 5, 4],
        [],
        [7],
    ],
    ids=["alternating", "runs", "unique", "nested", "empty", "single"],
)
def test_repair_roundtrip(seq):
    g = repair_compress(seq, alphabet=8)
    back = repair_expand_host(g, g.seq)
    np.testing.assert_array_equal(back, np.asarray(seq, dtype=np.int64))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 5), max_size=200))
def test_repair_roundtrip_property(seq):
    g = repair_compress(seq, alphabet=6)
    back = repair_expand_host(g, g.seq)
    np.testing.assert_array_equal(back, np.asarray(seq, dtype=np.int64))


def test_repair_compresses_repetitive():
    block = RNG.integers(0, 10, 16).tolist()
    seq = block * 50
    g = repair_compress(seq, alphabet=10)
    assert len(g.seq) < len(seq) / 8
    back = repair_expand_host(g, g.seq)
    np.testing.assert_array_equal(back, seq)


def test_repair_lists_shared_grammar():
    lists = [[1, 2, 3, 4], [1, 2, 3, 4, 5], [1, 2, 3, 4], [9], []]
    g, segs = repair_compress_lists(lists, alphabet=10)
    assert len(segs) == len(lists)
    for seg, orig in zip(segs, lists):
        back = repair_expand_host(g, seg)
        np.testing.assert_array_equal(back, np.asarray(orig, dtype=np.int64))
    # shared rule reused across lists 0 and 2 -> fewer total symbols
    assert sum(len(s) for s in segs) < sum(len(l) for l in lists)


def test_repair_aaa_overlap():
    seq = [2] * 9  # "aaaaaaaaa" with pair (2,2)
    g = repair_compress(seq, alphabet=3)
    back = repair_expand_host(g, g.seq)
    np.testing.assert_array_equal(back, seq)


# ---------------------------------------------------------------------------
# PDL
# ---------------------------------------------------------------------------


def make_fixture(docs, **pdl_kwargs):
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    csa = build_csa(data, sample_rate=4)
    index = build_pdl(data, **pdl_kwargs)
    return coll, data, csa, index


def oracle_docs(data, lo, hi):
    return sorted(set(data.da[lo:hi].tolist()))


def oracle_topk(data, lo, hi, k):
    from collections import Counter

    c = Counter(data.da[lo:hi].tolist())
    return sorted(c.items(), key=lambda kv: (-kv[1], kv[0]))[:k]


def _versions(n_docs=10, length=50, muts=3):
    base = "".join(RNG.choice(list("acgt"), length))
    out = []
    for _ in range(n_docs):
        b = list(base)
        for _ in range(muts):
            b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
        out.append("".join(b))
    return out


def patterns_for(docs, max_len=4):
    pats = set()
    for doc in docs:
        for m in range(1, max_len + 1):
            for i in range(0, max(1, len(doc) - m + 1), 3):
                pats.add(doc[i : i + m])
    return sorted(pats)


@pytest.mark.parametrize(
    "block_size,beta,mode",
    [
        (4, 1.0, "list"),
        (4, 16.0, "list"),
        (8, None, "list"),
        (4, 1.0, "topk"),
        (8, None, "topk"),
        (2, 4.0, "topk"),
    ],
)
def test_pdl_structure_invariants(block_size, beta, mode):
    docs = _versions(8, 40)
    coll, data, csa, index = make_fixture(
        docs, block_size=block_size, beta=beta, mode=mode
    )
    starts = np.asarray(index.leaf_starts)
    # tiling
    assert starts[0] == 0 and starts[-1] == coll.n
    assert (np.diff(starts) >= 1).all()
    assert (np.diff(starts) <= block_size).all()
    # first-child pointers well-formed
    pf = np.asarray(index.parent_of)
    isf = np.asarray(index.is_first_child)
    assert ((pf >= 0) == isf).all()
    if index.I:
        nl = np.asarray(index.next_leaf)
        assert (nl >= 1).all() and (nl <= index.L).all()


def test_pdl_listing_matches_oracle():
    docs = _versions(8, 40)
    coll, data, csa, index = make_fixture(docs, block_size=4, beta=2.0, mode="list")
    max_df = coll.d + 1
    for p in patterns_for(docs):
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        got_docs, cnt = pdl_list_docs(index, csa, lo, hi, max_df, max_buf=512)
        got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
        assert got == oracle_docs(data, lo, hi), (p, lo, hi)


def test_pdl_listing_beta_none():
    docs = _versions(6, 30)
    coll, data, csa, index = make_fixture(docs, block_size=8, beta=None, mode="list")
    max_df = coll.d + 1
    for p in patterns_for(docs)[::2]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        got_docs, cnt = pdl_list_docs(index, csa, lo, hi, max_df, max_buf=512)
        got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
        assert got == oracle_docs(data, lo, hi), p


@pytest.mark.parametrize("k", [1, 3, 10])
def test_pdl_topk_matches_oracle(k):
    docs = _versions(9, 45)
    coll, data, csa, index = make_fixture(docs, block_size=4, beta=2.0, mode="topk")
    for p in patterns_for(docs)[::2]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        topd, topf = pdl_topk(index, csa, lo, hi, k, max_buf=1024)
        got = [
            (int(a), int(b))
            for a, b in zip(np.asarray(topd), np.asarray(topf))
            if a >= 0
        ]
        assert got == oracle_topk(data, lo, hi, k), (p, k)


def test_pdl_topk_inverted_index_mode():
    """beta=None + freqs = the paper's PDL-b+F: every internal node stored."""
    docs = _versions(6, 30)
    coll, data, csa, index = make_fixture(docs, block_size=4, beta=None, mode="topk")
    for p in patterns_for(docs)[::3]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        topd, topf = pdl_topk(index, csa, lo, hi, 5, max_buf=1024)
        got = [
            (int(a), int(b))
            for a, b in zip(np.asarray(topd), np.asarray(topf))
            if a >= 0
        ]
        assert got == oracle_topk(data, lo, hi, 5), p


def test_pdl_repetitive_compresses():
    """On a repetitive collection the grammar-compressed lists must be much
    smaller than the raw stored lists."""
    docs = _versions(20, 60, muts=1)
    coll, data, csa, index = make_fixture(docs, block_size=4, beta=None, mode="list")
    raw_symbols = index.total_docs_stored
    stored_symbols = int(index.A.shape[0])
    assert stored_symbols < raw_symbols  # grammar won something
    assert index.modeled_bits() > 0


@settings(max_examples=10, deadline=None)
@given(
    st.lists(st.text(alphabet="ab", min_size=2, max_size=14), min_size=2, max_size=5),
    st.text(alphabet="ab", min_size=1, max_size=3),
)
def test_pdl_property(docs, pattern):
    coll, data, csa, index = make_fixture(docs, block_size=3, beta=1.0, mode="list")
    enc = encode_pattern(pattern)
    lo, hi = sa_range_for_pattern(data, enc)
    if lo >= hi:
        return
    got_docs, cnt = pdl_list_docs(index, csa, lo, hi, coll.d + 1, max_buf=256)
    got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
    assert got == oracle_docs(data, lo, hi)
