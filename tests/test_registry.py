"""Registry sanity: every (arch x shape) cell builds its abstract inputs and
specs on a (1,1) host mesh (no device allocation), trees line up, and the
reduced-config cells lower on the host mesh.

The FULL production-mesh lowering is exercised by launch.dryrun (80 cells,
see experiments/dryrun) — these tests keep the registry itself green in the
normal test run without 512 virtual devices.
"""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ALL_ARCHS, ARCH_SHAPES, build_cell
from repro.launch.mesh import make_host_mesh

MESH = make_host_mesh()


def _spec_leaves(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cells_build_and_trees_match(arch):
    for shape in ARCH_SHAPES[arch]:
        cell = build_cell(arch, shape, MESH)
        assert len(cell.abstract_args) == len(cell.in_specs)
        for args, specs in zip(cell.abstract_args, cell.in_specs):
            n_args = len(jax.tree.leaves(args))
            n_specs = len(_spec_leaves(specs))
            assert n_args == n_specs, (arch, shape)
        meta = cell.meta
        assert meta["model_flops"] > 0
        assert meta["analytic_flops"] >= meta["model_flops"] * 0.99
        assert meta["analytic_bytes"] > 0


@pytest.mark.parametrize("arch", ["smollm-135m", "fm", "nequip"])
def test_reduced_cells_lower_on_host_mesh(arch):
    shape = ARCH_SHAPES[arch][0]
    cell = build_cell(arch, shape, MESH, reduced=True)
    with MESH:
        lowered = jax.jit(cell.step_fn).lower(*cell.abstract_args)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_total_cell_count():
    total = sum(len(ARCH_SHAPES[a]) for a in ALL_ARCHS)
    assert total == 40


def test_dryrun_results_complete_if_present():
    """CI-style gate on the recorded multi-pod dry-run: when the results
    exist, all 80 cells must be OK with zero failures, every cell must
    carry the three roofline terms, and both meshes must appear."""
    import glob
    import json
    import os

    root = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    files = glob.glob(os.path.join(root, "*.json"))
    if not files:
        pytest.skip("dry-run results not generated in this environment")
    cells = 0
    meshes = set()
    for path in files:
        data = json.load(open(path))
        assert not data.get("failures"), (path, data["failures"])
        for r in data["results"]:
            cells += 1
            meshes.add(r["mesh"])
            rl = r["roofline"]
            for term in ("compute_s", "memory_s", "collective_s"):
                assert rl[term] >= 0
            assert rl["dominant"] in ("compute", "memory", "collective")
    assert cells == 80, cells
    assert meshes == {"16x16", "pod2x16x16"}
