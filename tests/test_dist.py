"""Distribution tests that need multiple (virtual) devices: run in a
subprocess with --xla_force_host_platform_device_count so the main pytest
process keeps its single-device JAX runtime."""

import os
import subprocess
import sys

import pytest

_SCRIPT_PARTITIONED_GNN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp

from repro.models.nequip import (
    NequIPConfig, init_params, forward_train, build_partition,
    partitioned_train_step_fn,
)

cfg = NequIPConfig(d_feat_in=6, channels=4, n_layers=2, n_rbf=4)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
rng = np.random.default_rng(0)
N, E, G = 32, 96, 2
node_feat = rng.standard_normal((N, 6)).astype(np.float32)
ei = rng.integers(0, N, (2, E)).astype(np.int32)
ev = (rng.standard_normal((E, 3)) * 2).astype(np.float32)
gid = np.sort(rng.integers(0, G, N)).astype(np.int32)
energy = rng.standard_normal(G).astype(np.float32)
batch_ref = dict(node_feat=jnp.asarray(node_feat), edge_index=jnp.asarray(ei),
                 edge_vec=jnp.asarray(ev), graph_id=jnp.asarray(gid),
                 energy=jnp.asarray(energy))
ref = float(forward_train(cfg, params, batch_ref, G))

mesh = jax.make_mesh((2, 2), ("data", "model"))
part = build_partition(node_feat, ei, ev, gid, ndev=4)
part["energy"] = energy
loss_fn = partitioned_train_step_fn(cfg, mesh, ("data", "model"), G)
with mesh:
    got = float(jax.jit(loss_fn)(params, {k: jnp.asarray(v) for k, v in part.items()}))
assert abs(got - ref) < 1e-3 * max(1.0, abs(ref)), (got, ref)

# gradients flow through the halo exchange
with mesh:
    g = jax.jit(jax.grad(loss_fn))(params, {k: jnp.asarray(v) for k, v in part.items()})
assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
print("PARTITIONED_OK", got, ref)
"""

_SCRIPT_EP_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.models.transformer import LMConfig, MoEConfig, init_params, forward_train

cfg0 = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=64, moe=MoEConfig(n_experts=4, capacity_factor=4.0),
                param_dtype=jnp.float32, act_dtype=jnp.float32)
key = jax.random.PRNGKey(0)
params = init_params(cfg0, key)
tokens = jax.random.randint(key, (4, 16), 0, 64)
ref = float(forward_train(cfg0, params, tokens, tokens))

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = dataclasses.replace(cfg0, ep_mesh=mesh, ep_dp_axes=("data",), ep_fsdp=False)
with mesh:
    got = float(jax.jit(lambda p, t: forward_train(cfg, p, t, t))(params, tokens))
# local-capacity dispatch may drop different tokens than global dispatch at
# tight capacity; with capacity_factor=E there are no drops at all
assert abs(got - ref) < 1e-4 * max(1.0, abs(ref)), (got, ref)
print("EP_OK", got, ref)
"""


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420, env=env,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_partitioned_gnn_matches_reference():
    out = _run(_SCRIPT_PARTITIONED_GNN)
    assert "PARTITIONED_OK" in out


def test_shard_map_moe_matches_local_dispatch():
    out = _run(_SCRIPT_EP_MOE)
    assert "EP_OK" in out
