"""Tests for the ILCP index (Section 3): run-length structure, document
listing (Fig 1), counting (Fig 3), against brute-force oracles; plus the
Brute/Sada-C baselines; plus the Lemma 2 run-growth property."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.csa import build_csa
from repro.core.ilcp import (
    SkewedWaveletTree,
    build_ilcp,
    ilcp_count_docs,
    ilcp_count_docs_batch,
    ilcp_list_docs_csa,
    ilcp_list_docs_da,
    ilcp_num_runs,
)
from repro.core.listing import (
    brute_list_csa,
    brute_list_da,
    brute_topk,
    sada_c_list_docs_da,
    sada_c_list_docs_csa,
)
from repro.succinct.rmq import rmq_build

RNG = np.random.default_rng(11)


def make_fixture(docs):
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    index = build_ilcp(data)
    csa = build_csa(data, sample_rate=4)
    da = jnp.asarray(data.da)
    return coll, data, index, csa, da


def oracle_docs(data, lo, hi):
    return sorted(set(data.da[lo:hi].tolist()))


def all_test_patterns(docs, max_len=4):
    pats = set()
    for doc in docs:
        s = doc if isinstance(doc, str) else "".join(chr(97 + x) for x in doc)
        for m in range(1, max_len + 1):
            for i in range(0, max(1, len(s) - m + 1), 2):
                pats.add(s[i : i + m])
    return sorted(p for p in pats if p)


DOC_SETS = {
    "paper": ["TATA", "LATA", "AAAA"],
    "versions": None,  # filled below
    "random": None,
}


def _make_versions():
    base = "".join(RNG.choice(list("acgt"), 60))
    docs = []
    for _ in range(8):
        b = list(base)
        for _ in range(3):
            b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
        docs.append("".join(b))
    return docs


DOC_SETS["versions"] = _make_versions()
DOC_SETS["random"] = ["".join(RNG.choice(list("ab"), RNG.integers(3, 25))) for _ in range(6)]


@pytest.fixture(scope="module", params=list(DOC_SETS))
def fixture(request):
    docs = DOC_SETS[request.param]
    return docs, *make_fixture(docs)


def test_ilcp_listing_da_matches_oracle(fixture):
    docs, coll, data, index, csa, da = fixture
    max_df = coll.d + 1
    for p in all_test_patterns(docs):
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        got_docs, cnt = ilcp_list_docs_da(index, da, lo, hi, max_df)
        got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
        assert got == oracle_docs(data, lo, hi), (p, lo, hi)


def test_ilcp_listing_csa_matches_oracle(fixture):
    docs, coll, data, index, csa, da = fixture
    max_df = coll.d + 1
    for p in all_test_patterns(docs)[::3]:  # subsample: locate is slower
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        got_docs, cnt = ilcp_list_docs_csa(index, csa, lo, hi, max_df)
        got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
        assert got == oracle_docs(data, lo, hi), (p, lo, hi)


def test_ilcp_counting_matches_oracle(fixture):
    docs, coll, data, index, csa, da = fixture
    los, his, ms, exp = [], [], [], []
    for p in all_test_patterns(docs):
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        los.append(lo)
        his.append(hi)
        ms.append(len(enc))
        exp.append(len(oracle_docs(data, lo, hi)))
    got = ilcp_count_docs_batch(
        index, jnp.asarray(los), jnp.asarray(his), jnp.asarray(ms)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_ilcp_counting_matches_skewed_wavelet_tree(fixture):
    """The jitted rank-descent counting must agree with the literal
    skewed-wavelet-tree traversal of Section 3.4 on run-head counts."""
    docs, coll, data, index, csa, da = fixture
    vilcp = np.asarray(index.vilcp)
    swt = SkewedWaveletTree(vilcp, int(vilcp.max()))
    # compare count-of-run-heads for value < m over whole VILCP
    from repro.succinct.wavelet import wm_count_less

    for m in [1, 2, 3, 5]:
        got = int(wm_count_less(index.wm, 0, len(vilcp), m))
        exp = swt.count_less(0, len(vilcp), m)
        assert got == exp, m


def test_brute_da_and_csa_match_oracle(fixture):
    docs, coll, data, index, csa, da = fixture
    max_occ = coll.n
    for p in all_test_patterns(docs)[::2]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        docs_d, cnt_d, freq_d = brute_list_da(da, lo, hi, max_occ)
        exp = oracle_docs(data, lo, hi)
        assert sorted(np.asarray(docs_d)[: int(cnt_d)].tolist()) == exp
        # frequencies
        from collections import Counter

        c = Counter(data.da[lo:hi].tolist())
        got_pairs = {
            int(doc): int(f)
            for doc, f in zip(np.asarray(docs_d)[: int(cnt_d)], np.asarray(freq_d))
        }
        assert got_pairs == dict(c)

        docs_l, cnt_l, freq_l = brute_list_csa(csa, lo, hi, max_occ)
        assert sorted(np.asarray(docs_l)[: int(cnt_l)].tolist()) == exp


def test_sada_c_matches_oracle(fixture):
    docs, coll, data, index, csa, da = fixture
    rmq_c = rmq_build(data.c)
    max_df = coll.d + 1
    for p in all_test_patterns(docs)[::2]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        got_docs, cnt = sada_c_list_docs_da(rmq_c, da, lo, hi, coll.d, max_df)
        got = sorted(np.asarray(got_docs)[: int(cnt)].tolist())
        assert got == oracle_docs(data, lo, hi), p


def test_brute_topk():
    docs, coll, data, index, csa, da = (
        DOC_SETS["versions"],
        *make_fixture(DOC_SETS["versions"]),
    )
    for p in ["a", "ac", "g"]:
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        d_, c_, f_ = brute_list_da(da, lo, hi, coll.n)
        for k in [1, 3, 8]:
            top_docs, top_freqs = brute_topk(d_, c_, f_, k)
            from collections import Counter

            cnt = Counter(data.da[lo:hi].tolist())
            expected = sorted(cnt.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
            got = [
                (int(a), int(b))
                for a, b in zip(np.asarray(top_docs), np.asarray(top_freqs))
                if a >= 0
            ]
            assert got == expected, (p, k)


# ---------------------------------------------------------------------------
# Lemma 2: runs grow with edits, not with copies
# ---------------------------------------------------------------------------


def test_ilcp_runs_lemma2():
    base = "".join(RNG.choice(list("acgt"), 100))
    d = 20

    def runs_with_mutations(n_mut):
        docs = []
        for _ in range(d):
            b = list(base)
            for _ in range(n_mut):
                b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
            docs.append("".join(b))
        coll = concat_documents(docs)
        return ilcp_num_runs(build_suffix_data(coll)), coll.n

    runs0, n = runs_with_mutations(0)
    runs3, _ = runs_with_mutations(3)
    runs10, _ = runs_with_mutations(10)
    # pure copies: rho <= r + 1 (base length + 1)
    assert runs0 <= len(base) + 2
    # runs grow roughly with edits, far below n
    assert runs0 <= runs3 <= runs10
    assert runs10 < n / 3


def test_modeled_sizes_reasonable():
    docs = DOC_SETS["versions"]
    coll, data, index, csa, da = make_fixture(docs)
    lb = index.modeled_bits_listing()
    cb = index.modeled_bits_counting()
    assert 0 < lb and 0 < cb
    # far below a plain DA (n lg d bits)
    import math

    plain_da_bits = coll.n * max(1, math.ceil(math.log2(coll.d)))
    assert lb < plain_da_bits


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.text(alphabet="ab", min_size=2, max_size=16), min_size=2, max_size=5),
    st.text(alphabet="ab", min_size=1, max_size=3),
)
def test_ilcp_property_listing_counting(docs, pattern):
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    index = build_ilcp(data)
    da = jnp.asarray(data.da)
    enc = encode_pattern(pattern)
    lo, hi = sa_range_for_pattern(data, enc)
    exp = oracle_docs(data, lo, hi)
    if lo < hi:
        got_docs, cnt = ilcp_list_docs_da(index, da, lo, hi, coll.d + 1)
        assert sorted(np.asarray(got_docs)[: int(cnt)].tolist()) == exp
    got_count = int(ilcp_count_docs(index, lo, hi, len(enc)))
    assert got_count == len(exp)
