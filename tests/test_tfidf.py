"""TF-IDF ranked multi-term queries vs a brute-force oracle."""

import numpy as np
import pytest

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.csa import build_csa
from repro.core.pdl import build_pdl
from repro.core.sada import build_sada
from repro.core.tfidf import tfidf_topk, tfidf_topk_batch, tfidf_topk_incremental

RNG = np.random.default_rng(41)


@pytest.fixture(scope="module")
def fixture():
    base = "the quick brown fox jumps over the lazy dog "
    docs = []
    for i in range(12):
        words = base.split()
        RNG.shuffle(words)
        extra = ["fox"] * (i % 4) + ["dog"] * (i % 3) + ["cat"] * (i % 2)
        docs.append(" ".join(words + extra))
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    csa = build_csa(data, sample_rate=4)
    pdl = build_pdl(data, block_size=8, beta=None, mode="topk")
    sada = build_sada(data, "sparse")
    return docs, coll, data, csa, pdl, sada


def oracle_tfidf(docs, data, terms, k, conjunctive):
    d = len(docs)
    # df and tf by substring counting over raw documents
    def count_occ(doc, t):
        c, start = 0, 0
        while True:
            j = doc.find(t, start)
            if j < 0:
                return c
            c += 1
            start = j + 1

    tfs = [[count_occ(doc, t) for doc in docs] for t in terms]
    dfs = [sum(1 for x in row if x > 0) for row in tfs]
    gs = [np.log2(d / max(df, 1)) for df in dfs]
    scores = {}
    for doc_id in range(d):
        if conjunctive and not all(tfs[t][doc_id] > 0 for t in range(len(terms))):
            continue
        w = sum(tfs[t][doc_id] * gs[t] for t in range(len(terms)))
        if any(tfs[t][doc_id] > 0 for t in range(len(terms))):
            scores[doc_id] = w
    ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
    return ranked


def ranges_for(data, terms, max_t=4):
    out = np.zeros((max_t, 2), dtype=np.int32)
    valid = np.zeros(max_t, dtype=bool)
    for i, t in enumerate(terms):
        lo, hi = sa_range_for_pattern(data, encode_pattern(t))
        out[i] = (lo, hi)
        valid[i] = True
    return out, valid


QUERIES = [
    (["fox"], False),
    (["fox", "dog"], False),
    (["fox", "dog"], True),
    (["fox", "dog", "cat"], False),
    (["fox", "dog", "cat"], True),
    (["quick", "lazy"], True),
    (["zebra"], False),
    (["zebra", "fox"], True),
]


@pytest.mark.parametrize("terms,conj", QUERIES)
@pytest.mark.parametrize("k", [3, 10])
def test_tfidf_matches_oracle(fixture, terms, conj, k):
    docs, coll, data, csa, pdl, sada = fixture
    ranges, valid = ranges_for(data, terms)
    topd, tops = tfidf_topk(pdl, csa, sada, ranges, valid, k, conj, max_buf=512)
    got = [
        (int(a), float(b))
        for a, b in zip(np.asarray(topd), np.asarray(tops))
        if a >= 0
    ]
    exp = oracle_tfidf(docs, data, terms, k, conj)
    assert [g[0] for g in got] == [e[0] for e in exp], (terms, conj, got, exp)
    for (_gd, gw), (_ed, ew) in zip(got, exp):
        assert abs(gw - ew) < 1e-3, (terms, conj)


def test_tfidf_batch(fixture):
    docs, coll, data, csa, pdl, sada = fixture
    rs, vs = [], []
    for terms, _conj in QUERIES[:4]:
        r, v = ranges_for(data, terms)
        rs.append(r)
        vs.append(v)
    topd, tops = tfidf_topk_batch(
        pdl, csa, sada, np.stack(rs), np.stack(vs), 5, False, max_buf=512
    )
    for qi, (terms, _) in enumerate(QUERIES[:4]):
        got = [int(a) for a in np.asarray(topd[qi]) if a >= 0]
        exp = [e[0] for e in oracle_tfidf(docs, data, terms, 5, False)]
        assert got == exp, terms


@pytest.mark.parametrize("terms,conj", [(["fox", "dog"], False), (["fox", "dog"], True), (["fox", "dog", "cat"], True)])
def test_tfidf_incremental_same_topk(fixture, terms, conj):
    docs, coll, data, csa, pdl, sada = fixture
    ranges, valid = ranges_for(data, terms)
    k = 5
    inc_docs, inc_w = tfidf_topk_incremental(
        pdl, csa, sada, ranges[: len(terms)], k, conj, max_buf=512
    )
    exp = oracle_tfidf(docs, data, terms, k, conj)
    assert inc_docs == [e[0] for e in exp], (terms, conj, inc_docs, exp)
