"""Unit + property tests for the succinct substrate.

Oracles are plain numpy computations; structures must agree exactly.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.succinct import (
    plain_from_bits,
    rle_from_bits,
    sparse_from_positions,
    wm_build,
    wm_access,
    wm_count_less,
    wm_rank,
    rmq_build,
    rmq_query,
)
from repro.succinct.bitvector import sparse_from_bits
from repro.succinct.wavelet import wm_symbol_range

RNG = np.random.default_rng(0)


def oracle_rank1(bits, i):
    return int(np.sum(bits[:i]))


def oracle_select1(bits, j):
    ones = np.flatnonzero(bits)
    return int(ones[j]) if j < len(ones) else len(bits)


def oracle_select0(bits, j):
    zeros = np.flatnonzero(1 - bits)
    return int(zeros[j]) if j < len(zeros) else len(bits)


def make_builders():
    return {
        "plain": plain_from_bits,
        "sparse": sparse_from_bits,
        "rle": rle_from_bits,
    }


@pytest.mark.parametrize("kind", ["plain", "sparse", "rle"])
@pytest.mark.parametrize(
    "bits",
    [
        np.array([1, 0, 1, 1, 0, 0, 0, 1], dtype=np.uint8),
        np.zeros(70, dtype=np.uint8),
        np.ones(70, dtype=np.uint8),
        (RNG.random(257) < 0.3).astype(np.uint8),
        (RNG.random(1024) < 0.9).astype(np.uint8),
        np.array([0, 0, 0, 1], dtype=np.uint8),
        np.array([1], dtype=np.uint8),
        np.array([0], dtype=np.uint8),
    ],
    ids=["small", "zeros", "ones", "sparse257", "dense1024", "tail1", "one1", "one0"],
)
def test_bitvector_rank_select_exhaustive(kind, bits):
    bv = make_builders()[kind](bits)
    n = len(bits)
    m = int(bits.sum())

    idx = jnp.arange(n + 1)
    ranks = jax.vmap(bv.rank1)(idx)
    expected = np.concatenate([[0], np.cumsum(bits)])
    np.testing.assert_array_equal(np.asarray(ranks), expected)

    ranks0 = jax.vmap(bv.rank0)(idx)
    np.testing.assert_array_equal(np.asarray(ranks0), idx - expected)

    if m:
        sel = jax.vmap(bv.select1)(jnp.arange(m))
        np.testing.assert_array_equal(np.asarray(sel), np.flatnonzero(bits))
    if n - m:
        sel0 = jax.vmap(bv.select0)(jnp.arange(n - m))
        np.testing.assert_array_equal(np.asarray(sel0), np.flatnonzero(1 - bits))

    # out-of-range select returns n
    assert int(bv.select1(m)) == n
    assert int(bv.select0(n - m)) == n

    # access
    got = np.asarray(jax.vmap(bv.get)(jnp.arange(n)))
    np.testing.assert_array_equal(got, bits.astype(np.int32))


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(0, 1), min_size=1, max_size=300),
    st.integers(0, 4),
)
def test_bitvector_property(bits, salt):
    bits = np.asarray(bits, dtype=np.uint8)
    n = len(bits)
    i = int((salt * 7919) % (n + 1))
    for builder in make_builders().values():
        bv = builder(bits)
        assert int(bv.rank1(i)) == oracle_rank1(bits, i)
        # rank/select inverses
        m = int(bits.sum())
        if m:
            j = salt % m
            p = int(bv.select1(j))
            assert bits[p] == 1
            assert int(bv.rank1(p)) == j


def test_rank_select_inverse_identity():
    bits = (RNG.random(500) < 0.4).astype(np.uint8)
    for builder in make_builders().values():
        bv = builder(bits)
        m = int(bits.sum())
        js = jnp.arange(m)
        sel = jax.vmap(bv.select1)(js)
        back = jax.vmap(bv.rank1)(sel)
        np.testing.assert_array_equal(np.asarray(back), np.arange(m))


def test_sparse_from_positions_empty():
    bv = sparse_from_positions(np.array([], dtype=np.int32), 10)
    assert int(bv.rank1(10)) == 0
    assert int(bv.select1(0)) == 10
    assert int(bv.select0(3)) == 3


# ---------------------------------------------------------------------------
# Wavelet matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sigma", [2, 3, 7, 16, 40])
def test_wavelet_rank_access(sigma):
    n = 400
    seq = RNG.integers(0, sigma, n)
    wm = wm_build(seq, sigma)

    # access
    got = np.asarray(jax.vmap(lambda i: wm_access(wm, i))(jnp.arange(n)))
    np.testing.assert_array_equal(got, seq)

    # rank_c at a grid of positions
    for c in range(sigma):
        pos = jnp.asarray([0, 1, n // 3, n // 2, n])
        r = jax.vmap(lambda i, c=c: wm_rank(wm, c, i))(pos)
        exp = [int(np.sum(seq[:p] == c)) for p in np.asarray(pos)]
        np.testing.assert_array_equal(np.asarray(r), exp)


@pytest.mark.parametrize("sigma", [2, 5, 16, 37])
def test_wavelet_pair_descent(sigma):
    """sym_starts / wm_descend / wm_rank_pair against ground truth: the
    precomputed block start makes rank_c one carried position per query,
    and the fused pair matches two independent classic ranks."""
    from repro.succinct.wavelet import wm_descend, wm_rank_pair

    n = 350
    seq = RNG.integers(0, sigma, n)
    wm = wm_build(seq, sigma)

    # sym_starts[c] is the descent of position 0 along c's bit path
    starts = np.asarray(wm.sym_starts)
    assert starts.shape == (sigma,)
    for c in range(sigma):
        assert int(wm_descend(wm, c, 0)) == starts[c]

    # scalar: rank via descend-minus-start, pair == two classic ranks
    for c in (0, sigma // 2, sigma - 1):
        for lo, hi in [(0, 0), (0, n), (3, n // 2), (n // 3, n)]:
            truth_lo = int(np.sum(seq[:lo] == c))
            truth_hi = int(np.sum(seq[:hi] == c))
            assert int(wm_descend(wm, c, lo)) - starts[c] == truth_lo
            a, b = wm_rank_pair(wm, c, lo, hi)
            assert (int(a), int(b)) == (truth_lo, truth_hi)

    # batched (elementwise arrays), against wm_rank
    B = 64
    c = jnp.asarray(RNG.integers(0, sigma, B), jnp.int32)
    lo = jnp.asarray(RNG.integers(0, n // 2, B), jnp.int32)
    hi = jnp.asarray(RNG.integers(0, n + 1, B), jnp.int32)
    a, b = wm_rank_pair(wm, c, lo, hi)
    exp_a = jax.vmap(lambda cc, i: wm_rank(wm, cc, i))(c, lo)
    exp_b = jax.vmap(lambda cc, i: wm_rank(wm, cc, i))(c, hi)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(exp_a))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(exp_b))


def test_wavelet_count_less():
    sigma = 13
    n = 300
    seq = RNG.integers(0, sigma, n)
    wm = wm_build(seq, sigma)
    cases = [(0, n, 5), (10, 200, 1), (0, 0, 3), (7, 8, 12), (0, n, 0), (0, n, sigma)]
    for lo, hi, m in cases:
        got = int(wm_count_less(wm, lo, hi, m))
        exp = int(np.sum(seq[lo:hi] < m))
        assert got == exp, (lo, hi, m)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(0, 15), min_size=1, max_size=200),
    st.integers(0, 17),
)
def test_wavelet_count_less_property(seq, m):
    seq = np.asarray(seq)
    wm = wm_build(seq, 16)
    lo, hi = 0, len(seq)
    assert int(wm_count_less(wm, lo, hi, m)) == int(np.sum(seq < m))


def test_wavelet_symbol_range():
    seq = np.array([3, 1, 3, 0, 3, 1, 2, 3])
    wm = wm_build(seq, 4)
    a, b = wm_symbol_range(wm, 3, 1, 7)  # occurrences of 3 in seq[1:7]
    # seq[1:7] = [1,3,0,3,1,2] -> two 3s, which are global occurrences 1 and 2
    assert (int(a), int(b)) == (1, 3)


# ---------------------------------------------------------------------------
# RMQ
# ---------------------------------------------------------------------------


def oracle_rmq_leftmost(values, lo, hi):
    seg = values[lo : hi + 1]
    return lo + int(np.argmin(seg))  # np.argmin returns leftmost min


@pytest.mark.parametrize("n", [1, 2, 3, 17, 64, 100])
def test_rmq_exhaustive_small(n):
    values = RNG.integers(0, 5, n)  # small range -> many ties
    rmq = rmq_build(values)
    for lo in range(n):
        for hi in range(lo, n):
            got = int(rmq_query(rmq, lo, hi))
            exp = oracle_rmq_leftmost(values, lo, hi)
            assert got == exp, (lo, hi, values.tolist())


def test_rmq_batched():
    n = 1000
    values = RNG.integers(-50, 50, n)
    rmq = rmq_build(values)
    los = RNG.integers(0, n, 200)
    his = np.minimum(los + RNG.integers(0, n, 200), n - 1)
    los = np.minimum(los, his)
    got = jax.vmap(lambda a, b: rmq_query(rmq, a, b))(jnp.asarray(los), jnp.asarray(his))
    for g, lo, hi in zip(np.asarray(got), los, his):
        assert g == oracle_rmq_leftmost(values, lo, hi)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-10, 10), min_size=1, max_size=120), st.data())
def test_rmq_property(values, data):
    values = np.asarray(values)
    n = len(values)
    lo = data.draw(st.integers(0, n - 1))
    hi = data.draw(st.integers(lo, n - 1))
    rmq = rmq_build(values)
    assert int(rmq_query(rmq, lo, hi)) == oracle_rmq_leftmost(values, lo, hi)


@pytest.mark.parametrize("n", [1, 2, 64, 100])
def test_rmq_degenerate_spans(n):
    """The spans the flattened-table kernels must not get wrong: single
    positions (hi == lo, span 1 -> k = 0), the full array (top-level k for
    power-of-two n, where the two table probes coincide), and every
    power-of-two span length where ``hi - 2^k + 1`` equals ``lo`` exactly."""
    values = RNG.integers(0, 4, n)  # ties force the leftmost rule to matter
    rmq = rmq_build(values)
    for lo in range(n):
        assert int(rmq_query(rmq, lo, lo)) == lo
    assert int(rmq_query(rmq, 0, n - 1)) == oracle_rmq_leftmost(values, 0, n - 1)
    k = 1
    while (1 << k) <= n:
        span = 1 << k
        for lo in (0, n - span):
            got = int(rmq_query(rmq, lo, lo + span - 1))
            assert got == oracle_rmq_leftmost(values, lo, lo + span - 1)
        k += 1


def test_modeled_bits_sane():
    bits = (RNG.random(10_000) < 0.01).astype(np.uint8)
    plain = plain_from_bits(bits).modeled_bits()
    sparse = sparse_from_bits(bits).modeled_bits()
    rle = rle_from_bits(bits).modeled_bits()
    # sparse/rle must beat plain on a 1% density vector
    assert sparse < plain
    assert rle < plain
