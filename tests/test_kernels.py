"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxpr import count_primitive
from repro.kernels import ops, ref
from repro.kernels.backward_search import backward_search_pallas
from repro.kernels.embedding_bag import csr_to_padded, embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rank import rank_pallas
from repro.kernels.rmq import rmq_pallas
from repro.succinct.bitvector import plain_from_bits
from repro.succinct.rmq import rmq_build
from repro.succinct.wavelet import wm_build

RNG = np.random.default_rng(53)


# ---------------------------------------------------------------------------
# rank
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 257, 4096])
@pytest.mark.parametrize("density", [0.02, 0.5, 0.97])
@pytest.mark.parametrize("block_q", [64, 256])
def test_rank_kernel(n, density, block_q):
    bits = (RNG.random(n) < density).astype(np.uint8)
    bv = plain_from_bits(bits)
    idx = jnp.asarray(RNG.integers(0, n + 1, 333), jnp.int32)
    got = rank_pallas(bv.words, bv.ones_prefix, idx, block_q=block_q, interpret=True)
    exp = ref.rank_ref(bv.words, bv.ones_prefix, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # and against the ground truth
    truth = np.concatenate([[0], np.cumsum(bits)])[np.asarray(idx)]
    np.testing.assert_array_equal(np.asarray(got), truth)


# ---------------------------------------------------------------------------
# rmq
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [16, 100, 1000])
@pytest.mark.parametrize("vrange", [3, 1000])
def test_rmq_kernel(n, vrange):
    values = RNG.integers(-vrange, vrange, n).astype(np.int32)
    st = rmq_build(values)
    q = 257
    lo = RNG.integers(0, n, q)
    hi = np.minimum(lo + RNG.integers(0, n, q), n - 1)
    lo = np.minimum(lo, hi)
    got = rmq_pallas(
        st.values, st.table, jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
        block_q=128, interpret=True,
    )
    exp = ref.rmq_ref(st.values, st.table, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    for g, a, b in zip(np.asarray(got)[:50], lo[:50], hi[:50]):
        assert g == a + int(np.argmin(values[a : b + 1]))


@pytest.mark.parametrize("n", [1, 64, 100])
def test_rmq_kernel_degenerate_spans(n):
    """Kernel parity on the spans that stress the two-probe trick:
    hi == lo (span 1), the full array (top-level k when n is a power of
    two), and spans where the second probe's start ``hi - 2^k + 1``
    coincides with ``lo``."""
    values = RNG.integers(0, 4, n).astype(np.int32)
    st = rmq_build(values)
    lo = [i for i in range(n)] + [0]
    hi = [i for i in range(n)] + [n - 1]
    k = 1
    while (1 << k) <= n:
        span = 1 << k
        lo += [0, n - span]
        hi += [span - 1, n - 1]
        k += 1
    got = rmq_pallas(
        st.values, st.table, jnp.asarray(lo, jnp.int32),
        jnp.asarray(hi, jnp.int32), block_q=64, interpret=True,
    )
    for g, a, b in zip(np.asarray(got), lo, hi):
        assert g == a + int(np.argmin(values[a : b + 1])), (a, b)


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("V,D,B,L", [(100, 16, 37, 4), (1000, 64, 128, 1), (50, 8, 5, 7)])
def test_embedding_bag_kernel(dtype, mode, V, D, B, L):
    table = jnp.asarray(RNG.standard_normal((V, D)), dtype)
    lens = RNG.integers(1, L + 1, B)
    indices = np.concatenate([RNG.integers(0, V, l) for l in lens]).astype(np.int32)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    padded = csr_to_padded(indices, offsets, L)
    got = embedding_bag_pallas(
        table, jnp.asarray(padded), mode=mode, block_b=32, interpret=True
    )
    exp = ref.embedding_bag_ref(
        table.astype(jnp.float32), jnp.asarray(indices), jnp.asarray(offsets), mode
    )
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,H,S,Dh", [(2, 2, 128, 32), (1, 4, 256, 64)])
def test_flash_attention_self(dtype, causal, B, H, S, Dh):
    q = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    got = flash_attention_pallas(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_decode_window():
    """S_kv > S_q (decode with KV cache): query i sees <= offset + i."""
    B, H, Sq, Skv, Dh = 1, 2, 64, 256, 32
    q = jnp.asarray(RNG.standard_normal((B, H, Sq, Dh)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, Skv, Dh)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, Skv, Dh)) * 0.5, jnp.float32)
    got = flash_attention_pallas(
        q, k, v, causal=True, block_q=32, block_k=64, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_attention_grad():
    """Kernel must be differentiable (training path)."""
    B, H, S, Dh = 1, 2, 128, 32
    q = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)

    def loss_kernel(q, k, v):
        return flash_attention_pallas(
            q, k, v, causal=True, block_q=64, block_k=64, interpret=True
        ).sum()

    def loss_ref(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# backward search (fused CSA range search)
# ---------------------------------------------------------------------------


def _bws_index(n, sigma, seed):
    """Wavelet matrix over a random sequence + the FM-index base array
    (C[c] - sym_starts[c]); returns the raw sequence for ground truth."""
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n)
    wm = wm_build(seq, sigma)
    counts = np.concatenate([[0], np.cumsum(np.bincount(seq, minlength=sigma))])
    base = jnp.asarray(counts[:sigma], jnp.int32) - wm.sym_starts
    return seq, wm, base, counts


def _bws_truth(seq, counts, n, sigma, pat):
    """Textbook per-symbol backward search with the serving layer's
    conventions: empty pattern -> (0, n); out-of-alphabet symbol collapses
    to the empty range at its lexicographic insertion point."""
    lo, hi = 0, n
    for c in map(int, reversed(pat)):
        if lo >= hi:
            break
        if c < 0 or c >= sigma:
            lo = hi = 0 if c < 0 else n
            break
        lo = int(counts[c]) + int(np.sum(seq[:lo] == c))
        hi = int(counts[c]) + int(np.sum(seq[:hi] == c))
    return lo, max(lo, hi)


def _bws_patterns(seq, sigma, Q, max_m, seed, oob=True):
    rng = np.random.default_rng(seed)
    pats = np.zeros((Q, max_m), np.int32)
    lens = rng.integers(0, max_m + 1, Q).astype(np.int32)
    for qi in range(Q):
        m = int(lens[qi])
        if m == 0:
            continue
        if rng.random() < 0.5 and m <= len(seq):
            start = rng.integers(0, len(seq) - m + 1)
            pats[qi, :m] = seq[start : start + m]  # guaranteed hits
        else:
            pats[qi, :m] = rng.integers(0, sigma, m)
        if oob and rng.random() < 0.25:
            pats[qi, rng.integers(0, m)] = rng.choice(
                [-3, -1, sigma, sigma + 5]
            )
    return jnp.asarray(pats), jnp.asarray(lens)


def _reversed_pats(pats, lens):
    """Right-to-left symbol order, as ops.backward_search materialises it."""
    B, max_m = pats.shape
    j = jnp.clip(
        lens[:, None] - 1 - jnp.arange(max_m, dtype=jnp.int32)[None, :],
        0, max(max_m - 1, 0),
    )
    return jnp.take_along_axis(pats, j, axis=1)


@pytest.mark.parametrize("sigma", [2, 5, 37])
@pytest.mark.parametrize("Q,block_q", [(1, 256), (33, 8), (64, 16)])
def test_backward_search_kernel(sigma, Q, block_q):
    """Interpret-mode kernel == ref oracle == ground truth, including Q not
    a multiple of block_q and out-of-alphabet symbols."""
    n, max_m = 500, 9
    seq, wm, base, counts = _bws_index(n, sigma, seed=sigma)
    pats, lens = _bws_patterns(seq, sigma, Q, max_m, seed=Q * 31 + sigma)

    lo_k, hi_k = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base, pats, lens,
        n=n, sigma=sigma, block_q=block_q, interpret=True,
    )
    rev = _reversed_pats(pats, lens)
    lo_r, hi_r = ref.backward_search_ref(
        wm.words, wm.ones_prefix, wm.zcount, base, rev, lens, n=n, sigma=sigma
    )
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_r))
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_r))
    # and the raw kernel entry point (wrapper-materialised reversal aside)
    lo_p, hi_p = backward_search_pallas(
        wm.words, wm.ones_prefix, wm.zcount, base, rev, lens,
        n=n, sigma=sigma, block_q=block_q, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(lo_p), np.asarray(lo_r))
    np.testing.assert_array_equal(np.asarray(hi_p), np.asarray(hi_r))
    for qi in range(Q):
        lo_t, hi_t = _bws_truth(
            seq, counts, n, sigma, np.asarray(pats[qi, : lens[qi]])
        )
        assert (int(lo_k[qi]), int(hi_k[qi])) == (lo_t, hi_t), f"query {qi}"


def test_backward_search_oob_stays_empty():
    """Any out-of-alphabet symbol must collapse the range to empty and keep
    it empty through the remaining (earlier) symbols."""
    n, sigma, max_m = 300, 6, 7
    seq, wm, base, _ = _bws_index(n, sigma, seed=2)
    rng = np.random.default_rng(7)
    pats = rng.integers(0, sigma, (32, max_m)).astype(np.int32)
    lens = np.full(32, max_m, np.int32)
    pats[:, 3] = np.where(np.arange(32) % 2 == 0, sigma + 4, -2)
    lo, hi = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base,
        jnp.asarray(pats), jnp.asarray(lens),
        n=n, sigma=sigma, block_q=8, interpret=True,
    )
    assert np.array_equal(np.asarray(lo), np.asarray(hi))


def test_backward_search_odd_shape_fallback(monkeypatch):
    """Empty batch / zero-width patterns / over-budget indexes must take the
    pure-jnp path: correct results, zero pallas_call in the jaxpr."""
    n, sigma, max_m = 200, 5, 6
    seq, wm, base, counts = _bws_index(n, sigma, seed=4)

    def launches(pats, lens):
        fn = lambda p, l: ops.backward_search(  # noqa: E731
            wm.words, wm.ones_prefix, wm.zcount, base, p, l,
            n=n, sigma=sigma, interpret=True,
        )
        return count_primitive(jax.make_jaxpr(fn)(pats, lens).jaxpr, "pallas_call")

    # B == 0
    e_pats = jnp.zeros((0, max_m), jnp.int32)
    e_lens = jnp.zeros(0, jnp.int32)
    assert launches(e_pats, e_lens) == 0
    lo, hi = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base, e_pats, e_lens,
        n=n, sigma=sigma, interpret=True,
    )
    assert lo.shape == (0,) and hi.shape == (0,)

    # max_m == 0: every row is the empty pattern -> full range (0, n)
    z_pats = jnp.zeros((4, 0), jnp.int32)
    z_lens = jnp.zeros(4, jnp.int32)
    assert launches(z_pats, z_lens) == 0
    lo, hi = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base, z_pats, z_lens,
        n=n, sigma=sigma, interpret=True,
    )
    assert np.all(np.asarray(lo) == 0) and np.all(np.asarray(hi) == n)

    # over the VMEM budget: same integers through the oracle, no launch
    pats, lens = _bws_patterns(seq, sigma, 16, max_m, seed=11)
    want = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base, pats, lens,
        n=n, sigma=sigma, interpret=True,
    )
    monkeypatch.setattr(ops, "BACKWARD_SEARCH_VMEM_BUDGET", 1)
    assert launches(pats, lens) == 0
    got = ops.backward_search(
        wm.words, wm.ones_prefix, wm.zcount, base, pats, lens,
        n=n, sigma=sigma, interpret=True,
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_backward_search_single_launch():
    """The launch-count contract: the whole planned range search for a
    padded batch is exactly ONE pallas_call (down from 2*m*levels rank
    calls); the XLA fallback is zero launches and bit-identical."""
    from repro.core.csa import build_csa, csa_search_planned
    from repro.core.suffix import build_suffix_data
    from repro.data.collections import SyntheticSpec, generate

    coll = generate(
        SyntheticSpec("version", n_base=2, n_variants=4, base_len=60,
                      mutation_rate=0.01, seed=7)
    )
    csa = build_csa(build_suffix_data(coll))
    pats = jnp.asarray(RNG.integers(0, coll.sigma, (8, 16)), jnp.int32)
    lens = jnp.asarray(RNG.integers(0, 17, 8), jnp.int32)

    kern = lambda p, l: csa_search_planned(  # noqa: E731
        csa, p, l, use_kernel=True, interpret=True
    )
    fall = lambda p, l: csa_search_planned(csa, p, l, use_kernel=False)  # noqa: E731
    assert count_primitive(jax.make_jaxpr(kern)(pats, lens).jaxpr, "pallas_call") == 1
    assert count_primitive(jax.make_jaxpr(fall)(pats, lens).jaxpr, "pallas_call") == 0

    lo_k, hi_k = kern(pats, lens)
    lo_f, hi_f = fall(pats, lens)
    np.testing.assert_array_equal(np.asarray(lo_k), np.asarray(lo_f))
    np.testing.assert_array_equal(np.asarray(hi_k), np.asarray(hi_f))


def test_pair_descent_halves_gathers():
    """The XLA fallback contract: a fused (lo, hi) pair descent issues half
    the per-level rank gathers of two independent wm_rank_batch descents."""
    from repro.succinct.wavelet import wm_rank_batch, wm_rank_pair_batch

    _, wm, _, _ = _bws_index(600, 13, seed=3)
    c = jnp.asarray(RNG.integers(0, 13, 64), jnp.int32)
    lo = jnp.asarray(RNG.integers(0, 300, 64), jnp.int32)
    hi = jnp.asarray(RNG.integers(300, 601, 64), jnp.int32)

    pair = jax.make_jaxpr(lambda c, a, b: wm_rank_pair_batch(wm, c, a, b))(
        c, lo, hi
    )
    dual = jax.make_jaxpr(
        lambda c, a, b: (wm_rank_batch(wm, c, a), wm_rank_batch(wm, c, b))
    )(c, lo, hi)
    g_pair = count_primitive(pair.jaxpr, "gather")
    g_dual = count_primitive(dual.jaxpr, "gather")
    # pair: 2 rank gathers/level + one sym_starts lookup outside the loop;
    # dual: 4 rank gathers/level (each wm_rank carries a (start, end) pair)
    assert g_pair * 2 <= g_dual + 2, (g_pair, g_dual)

    # and the integers agree with the classic descent
    rl_p, rh_p = wm_rank_pair_batch(wm, c, lo, hi)
    np.testing.assert_array_equal(
        np.asarray(rl_p), np.asarray(wm_rank_batch(wm, c, lo))
    )
    np.testing.assert_array_equal(
        np.asarray(rh_p), np.asarray(wm_rank_batch(wm, c, hi))
    )


# ---------------------------------------------------------------------------
# fused ILCP document listing
# ---------------------------------------------------------------------------


def _ilcp_fixture(seed=13):
    """A repetitive versioned collection with pattern-derived SA ranges —
    the ILCP recursion's completeness (Lemma 3) holds on pattern ranges,
    so ground-truth checks must use real ones, not random intervals."""
    from repro.core.ilcp import build_ilcp
    from repro.core.suffix import build_suffix_data, sa_range_for_pattern
    from repro.data.collections import (
        SyntheticSpec, generate, random_substring_patterns,
    )

    coll = generate(SyntheticSpec(
        "version", n_base=2, n_variants=6, base_len=80,
        mutation_rate=0.02, seed=seed,
    ))
    data = build_suffix_data(coll)
    index = build_ilcp(data)
    pats = random_substring_patterns(coll, 300, 5, 32)
    ranges = [sa_range_for_pattern(data, p) for p in pats]
    ranges += [(0, 0), (5, 5), (7, 3)]  # empty + inverted ranges
    lo = jnp.asarray([r[0] for r in ranges], jnp.int32)
    hi = jnp.asarray([r[1] for r in ranges], jnp.int32)
    return coll, data, index, jnp.asarray(data.da), lo, hi


def _list_launches(fn, *args):
    # fresh wrapper per call: make_jaxpr caches on (fn identity, avals),
    # and these tests re-trace the same fn after flipping a module global
    fresh = lambda *a: fn(*a)  # noqa: E731
    return count_primitive(jax.make_jaxpr(fresh)(*args).jaxpr, "pallas_call")


@pytest.mark.parametrize("max_df,block_q", [(2, 128), (8, 4), (64, 128)])
def test_ilcp_list_kernel_parity(max_df, block_q):
    """Kernel vs lockstep oracle vs the vmapped Fig-1 recursion: all three
    bit-identical (same documents in the same discovery order), and the
    distinct-document sets match numpy ground truth on pattern SA ranges —
    including df > max_df truncation at small max_df and odd batch shapes
    (B not a multiple of block_q)."""
    from repro.core.ilcp import ilcp_list_docs_da_batch

    coll, data, index, da, lo, hi = _ilcp_fixture()
    kw = dict(d=coll.d, max_df=max_df)
    docs_k, cnt_k = ops.ilcp_list(
        index.vilcp, index.rmq.table, index.run_starts, da, lo, hi,
        block_q=block_q, interpret=True, **kw,
    )
    lo_run = ops.runs_of(index.run_starts, lo)
    hi_run = ops.runs_of(index.run_starts, hi - 1)
    docs_o, cnt_o = ref.ilcp_list_ref(
        index.vilcp, index.rmq.table, index.run_starts, da, lo, hi,
        lo_run, hi_run, **kw,
    )
    docs_v, cnt_v = ilcp_list_docs_da_batch(index, da, lo, hi, max_df)
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_o))
    np.testing.assert_array_equal(np.asarray(docs_k), np.asarray(docs_o))
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_v))
    np.testing.assert_array_equal(
        np.asarray(docs_k), np.asarray(docs_v)[:, :max_df]
    )

    danp = np.asarray(data.da)
    for i in range(lo.shape[0]):
        a, b = int(lo[i]), int(hi[i])
        truth = sorted(set(danp[a:b].tolist())) if a < b else []
        got = np.asarray(docs_k)[i, : int(cnt_k[i])].tolist()
        assert len(set(got)) == len(got), "duplicate docs reported"
        if len(truth) <= max_df:
            assert sorted(got) == truth, (a, b)
        else:
            assert int(cnt_k[i]) == max_df
            assert set(got) <= set(truth), (a, b)


def test_ilcp_list_launch_and_fallbacks(monkeypatch):
    """Launch-count + fallback contract of the ``ops.ilcp_list`` wrapper:
    ONE pallas_call on the kernel path; zero for B == 0, max_df == 0, and
    a pinched VMEM budget — each fallback bit-identical to the kernel."""
    coll, data, index, da, lo, hi = _ilcp_fixture()

    def run(l, h, max_df=8):
        return ops.ilcp_list(
            index.vilcp, index.rmq.table, index.run_starts, da, l, h,
            d=coll.d, max_df=max_df, interpret=True,
        )

    assert _list_launches(run, lo, hi) == 1
    want = run(lo, hi)

    # B == 0: no launch, empty outputs
    e = jnp.zeros(0, jnp.int32)
    assert _list_launches(run, e, e) == 0
    docs0, cnt0 = run(e, e)
    assert docs0.shape == (0, 8) and cnt0.shape == (0,)

    # max_df == 0 routes to the oracle
    assert _list_launches(lambda a, b: run(a, b, max_df=0), lo, hi) == 0

    # over the VMEM budget: same integers through the oracle, no launch
    monkeypatch.setattr(ops, "ILCP_LIST_VMEM_BUDGET", 1)
    assert _list_launches(run, lo, hi) == 0
    got = run(lo, hi)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ilcp_list_rmq_kernel_fallback():
    """Satellite wiring: the XLA fallback recursion can batch its RMQ
    probes through the orphaned Pallas RMQ kernel (one launch — the RMQ
    inside the loop body) and stays bit-identical to the plain path."""
    from repro.core.ilcp import ilcp_list_docs_da_batch

    coll, data, index, da, lo, hi = _ilcp_fixture()
    plain = ilcp_list_docs_da_batch(index, da, lo, hi, 8)
    rmqk = ilcp_list_docs_da_batch(index, da, lo, hi, 8, use_rmq_kernel=True)
    for g, w in zip(rmqk, plain):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    n = _list_launches(
        lambda a, b: ilcp_list_docs_da_batch(
            index, da, a, b, 8, use_rmq_kernel=True
        ),
        lo, hi,
    )
    assert n == 1


def test_ilcp_list_oob_range_stays_empty():
    """Degenerate SA bounds past the array ends must not fabricate
    documents — the kernel clips its gathers, so cnt stays 0 for empty
    and inverted ranges even at the extremes."""
    coll, data, index, da, _, _ = _ilcp_fixture()
    n = int(da.shape[0])
    lo = jnp.asarray([0, n, n - 1, 17], jnp.int32)
    hi = jnp.asarray([0, n, n - 1, 2], jnp.int32)
    docs, cnt = ops.ilcp_list(
        index.vilcp, index.rmq.table, index.run_starts, da, lo, hi,
        d=coll.d, max_df=8, interpret=True,
    )
    assert np.asarray(cnt).tolist() == [0, 0, 0, 0]
    assert np.all(np.asarray(docs) == -1)


def test_list_endpoint_two_launches():
    """The list endpoint's launch-count contract at the program level:
    kernel path = exactly TWO pallas_calls (fused backward search + fused
    listing), XLA path = zero, and the two programs agree end to end."""
    from repro.data.collections import SyntheticSpec, generate
    from repro.serve.retrieval import RetrievalService

    coll = generate(SyntheticSpec(
        "version", n_base=2, n_variants=4, base_len=60,
        mutation_rate=0.01, seed=7,
    ))
    svc = RetrievalService.build(coll, validate=False)
    on = svc.trace_endpoint("list", use_kernel=True, use_list_kernel=True)
    off = svc.trace_endpoint("list", use_kernel=False, use_list_kernel=False)
    assert count_primitive(on.jaxpr, "pallas_call") == 2
    assert count_primitive(off.jaxpr, "pallas_call") == 0
