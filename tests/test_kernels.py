"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import csr_to_padded, embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rank import rank_pallas
from repro.kernels.rmq import rmq_pallas
from repro.succinct.bitvector import plain_from_bits
from repro.succinct.rmq import rmq_build

RNG = np.random.default_rng(53)


# ---------------------------------------------------------------------------
# rank
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 257, 4096])
@pytest.mark.parametrize("density", [0.02, 0.5, 0.97])
@pytest.mark.parametrize("block_q", [64, 256])
def test_rank_kernel(n, density, block_q):
    bits = (RNG.random(n) < density).astype(np.uint8)
    bv = plain_from_bits(bits)
    idx = jnp.asarray(RNG.integers(0, n + 1, 333), jnp.int32)
    got = rank_pallas(bv.words, bv.ones_prefix, idx, block_q=block_q, interpret=True)
    exp = ref.rank_ref(bv.words, bv.ones_prefix, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # and against the ground truth
    truth = np.concatenate([[0], np.cumsum(bits)])[np.asarray(idx)]
    np.testing.assert_array_equal(np.asarray(got), truth)


# ---------------------------------------------------------------------------
# rmq
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [16, 100, 1000])
@pytest.mark.parametrize("vrange", [3, 1000])
def test_rmq_kernel(n, vrange):
    values = RNG.integers(-vrange, vrange, n).astype(np.int32)
    st = rmq_build(values)
    q = 257
    lo = RNG.integers(0, n, q)
    hi = np.minimum(lo + RNG.integers(0, n, q), n - 1)
    lo = np.minimum(lo, hi)
    got = rmq_pallas(
        st.values, st.table, jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
        block_q=128, interpret=True,
    )
    exp = ref.rmq_ref(st.values, st.table, jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    for g, a, b in zip(np.asarray(got)[:50], lo[:50], hi[:50]):
        assert g == a + int(np.argmin(values[a : b + 1]))


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("V,D,B,L", [(100, 16, 37, 4), (1000, 64, 128, 1), (50, 8, 5, 7)])
def test_embedding_bag_kernel(dtype, mode, V, D, B, L):
    table = jnp.asarray(RNG.standard_normal((V, D)), dtype)
    lens = RNG.integers(1, L + 1, B)
    indices = np.concatenate([RNG.integers(0, V, l) for l in lens]).astype(np.int32)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    padded = csr_to_padded(indices, offsets, L)
    got = embedding_bag_pallas(
        table, jnp.asarray(padded), mode=mode, block_b=32, interpret=True
    )
    exp = ref.embedding_bag_ref(
        table.astype(jnp.float32), jnp.asarray(indices), jnp.asarray(offsets), mode
    )
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,H,S,Dh", [(2, 2, 128, 32), (1, 4, 256, 64)])
def test_flash_attention_self(dtype, causal, B, H, S, Dh):
    q = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.5, dtype)
    got = flash_attention_pallas(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_decode_window():
    """S_kv > S_q (decode with KV cache): query i sees <= offset + i."""
    B, H, Sq, Skv, Dh = 1, 2, 64, 256, 32
    q = jnp.asarray(RNG.standard_normal((B, H, Sq, Dh)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, Skv, Dh)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, Skv, Dh)) * 0.5, jnp.float32)
    got = flash_attention_pallas(
        q, k, v, causal=True, block_q=32, block_k=64, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_attention_grad():
    """Kernel must be differentiable (training path)."""
    B, H, S, Dh = 1, 2, 128, 32
    q = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dh)) * 0.3, jnp.float32)

    def loss_kernel(q, k, v):
        return flash_attention_pallas(
            q, k, v, causal=True, block_q=64, block_k=64, interpret=True
        ).sum()

    def loss_ref(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)
