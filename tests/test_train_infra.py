"""Training-infrastructure tests: atomic checkpointing, crash recovery,
elastic restore, gradient compression convergence parity, straggler
accounting, and the data pipelines."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import LMConfig, forward_train, init_params
from repro.train.checkpoint import (
    latest_checkpoint,
    list_checkpoints,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.compression import (
    compressed_grads,
    compression_ratio,
    init_error_state,
)
from repro.train.loop import FailureInjector, train, train_with_recovery
from repro.train.optimizer import AdamWConfig, adamw_init

CFG = LMConfig(
    name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab=61, param_dtype=jnp.float32, act_dtype=jnp.float32,
)
KEY = jax.random.PRNGKey(0)


def batch_fn(step):
    rng = np.random.default_rng(step)
    t = rng.integers(0, 61, (4, 16)).astype(np.int32)
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}


def loss_fn(params, batch):
    return forward_train(CFG, params, batch["tokens"], batch["labels"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    params = init_params(CFG, KEY)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    path = save_checkpoint(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "COMMITTED"))
    restored, step = restore_checkpoint(path, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_uncommitted_ignored(tmp_path):
    params = init_params(CFG, KEY)
    save_checkpoint(str(tmp_path), 1, params)
    # simulate a crash mid-save: stage dir without COMMITTED
    bad = tmp_path / "step_0000000002"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"junk")
    latest = latest_checkpoint(str(tmp_path))
    assert latest is not None and latest[0] == 1


def test_checkpoint_prune(tmp_path):
    params = {"w": jnp.ones(3)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, params)
    prune_checkpoints(str(tmp_path), keep=2)
    assert [s for s, _ in list_checkpoints(str(tmp_path))] == [4, 5]


def test_elastic_restore_respects_sharding(tmp_path):
    """Restore onto a (1,1) mesh with NamedSharding (elastic re-mesh path)."""
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import PartitionSpec as P

    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 3, params)
    mesh = make_host_mesh()
    specs = {"w": P(None, None)}
    restored, step = restore_checkpoint(
        latest_checkpoint(str(tmp_path))[1], params, mesh, specs
    )
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(params["w"]))
    assert restored["w"].sharding.mesh.shape == mesh.shape


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_training_with_injected_failure_recovers(tmp_path):
    res = train_with_recovery(
        loss_fn,
        lambda: init_params(CFG, KEY),
        batch_fn,
        n_steps=12,
        ckpt_dir=str(tmp_path),
        ckpt_every=4,
        failure=FailureInjector(fail_at_step=6),
    )
    assert res.final_step == 12
    assert res.restarts >= 1
    # the run must have resumed from step 4's checkpoint, not restarted at 0
    steps = [s for s, _ in list_checkpoints(str(tmp_path))]
    assert 12 in steps


def test_training_loss_decreases(tmp_path):
    fixed = batch_fn(0)  # overfit one batch: loss must drop
    res = train(
        loss_fn, lambda: init_params(CFG, KEY), lambda step: fixed,
        n_steps=30, ckpt_dir=str(tmp_path), ckpt_every=50,
        opt_cfg=AdamWConfig(lr=1e-2, weight_decay=0.0),
    )
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_roundtrip_small_error():
    params = init_params(CFG, KEY)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    err = init_error_state(params)
    eff, new_err = compressed_grads(g, err)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(eff)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    assert compression_ratio(g) < 0.3  # int8 + scales vs f32


def test_error_feedback_accumulates():
    """Quantization error must be carried, not dropped: the sum of applied
    updates over steps converges to the true sum."""
    g = {"w": jnp.full((512,), 1e-4, jnp.float32)}  # below one quant step
    err = init_error_state(g)
    total = np.zeros(512, np.float32)
    for _ in range(200):
        eff, err = compressed_grads(g, err)
        total += np.asarray(eff["w"])
    np.testing.assert_allclose(total, 200 * 1e-4, rtol=0.05)


def test_compressed_training_parity(tmp_path):
    kw = dict(
        loss_fn=loss_fn, init_params_fn=lambda: init_params(CFG, KEY),
        batch_fn=batch_fn, n_steps=25, ckpt_every=100,
        opt_cfg=AdamWConfig(lr=1e-2, weight_decay=0.0),
    )
    base = train(ckpt_dir=str(tmp_path / "a"), **kw)
    comp = train(ckpt_dir=str(tmp_path / "b"), compress_grads=True, **kw)
    # int8 EF training must track uncompressed loss closely
    assert abs(np.mean(comp.losses[-5:]) - np.mean(base.losses[-5:])) < 0.25


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------


def test_lm_pipeline_and_prefetch():
    from repro.data.pipelines import Prefetcher, lm_batches

    it = Prefetcher(lm_batches(vocab=100, batch=4, seq=8))
    b = next(it)
    assert b["tokens"].shape == (4, 8)
    assert b["tokens"].max() < 100
    it.close()


def test_neighbor_sampler_shapes():
    from repro.data.pipelines import build_csr, neighbor_sample, random_graph

    g = random_graph(200, 1000, 8)
    indptr, nbrs = build_csr(200, g["edge_index"])
    seeds = np.arange(10)
    nodes, edge_index = neighbor_sample(indptr, nbrs, seeds, fanouts=(5, 3))
    assert edge_index.shape[0] == 2
    # layer 1: 10*5 edges; layer 2: fanout 3 per newly discovered node
    assert edge_index.shape[1] >= 50
    assert edge_index.max() < len(nodes)
    # all seed nodes are the first ids
    np.testing.assert_array_equal(nodes[:10], seeds)


def test_synthetic_collections_runs_property():
    """Lemma 2 behaviour on the paper's synthetic families: lower mutation
    rate => fewer ILCP runs."""
    from repro.core.ilcp import ilcp_num_runs
    from repro.core.suffix import build_suffix_data
    from repro.data.collections import SyntheticSpec, generate

    lo = generate(SyntheticSpec("version", 2, 10, 200, 0.001))
    hi = generate(SyntheticSpec("version", 2, 10, 200, 0.1))
    r_lo = ilcp_num_runs(build_suffix_data(lo))
    r_hi = ilcp_num_runs(build_suffix_data(hi))
    assert r_lo < r_hi


def test_recsys_pipeline():
    from repro.data.pipelines import recsys_batches

    it = recsys_batches((10, 20, 30), batch=16, n_dense=4)
    b = next(it)
    assert b["sparse"].shape == (16, 3)
    assert (b["sparse"] < np.asarray([10, 20, 30])).all()
    assert b["dense"].shape == (16, 4)
