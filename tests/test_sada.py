"""Tests for Sadakane's counting structure: exactness on every suffix-tree
node range (all variants), paper example, run-growth behaviour (Section 5.3),
and agreement with ILCP counting on pattern loci."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.suffix import (
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.sada import (
    VARIANTS,
    build_sada,
    compute_h_slots,
    hprime_runs_of_ones,
    sada_count,
    sada_count_batch,
)
from repro.core.sufftree import lcp_interval_tree

RNG = np.random.default_rng(31)


def _versions(n_docs=8, length=40, muts=2, alpha="acgt"):
    base = "".join(RNG.choice(list(alpha), length))
    out = []
    for _ in range(n_docs):
        b = list(base)
        for _ in range(muts):
            b[RNG.integers(0, len(b))] = RNG.choice(list(alpha))
        out.append("".join(b))
    return out


DOCSETS = {
    "paper": ["TATA", "LATA", "AAAA"],
    "versions": _versions(),
    "random": ["".join(RNG.choice(list("ab"), RNG.integers(3, 30))) for _ in range(7)],
    "identical": ["abcabc"] * 5,
}


@pytest.fixture(scope="module", params=list(DOCSETS))
def fixture(request):
    docs = DOCSETS[request.param]
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    return docs, coll, data


@pytest.mark.parametrize("variant", VARIANTS)
def test_sada_exact_on_all_nodes(fixture, variant):
    """df must be exact for every lcp-interval (suffix-tree node) range —
    the structure's contract."""
    docs, coll, data = fixture
    s = build_sada(data, variant)
    tree = lcp_interval_tree(data.lcp)
    los = tree.lo.astype(np.int32)
    his = tree.hi.astype(np.int32)
    got = np.asarray(sada_count_batch(s, jnp.asarray(los), jnp.asarray(his)))
    for g, lo, hi in zip(got, los, his):
        exp = len(set(data.da[lo:hi].tolist()))
        assert g == exp, (variant, lo, hi)


@pytest.mark.parametrize("variant", ["plain", "sparse"])
def test_sada_on_pattern_loci(fixture, variant):
    docs, coll, data = fixture
    s = build_sada(data, variant)
    pats = set()
    for doc in docs:
        for m in (1, 2, 3):
            for i in range(0, max(1, len(doc) - m), 2):
                pats.add(doc[i : i + m])
    for p in sorted(pats):
        lo, hi = sa_range_for_pattern(data, encode_pattern(p))
        if lo >= hi:
            continue
        # pattern loci are node ranges or single suffixes
        got = int(sada_count(s, lo, hi))
        exp = len(set(data.da[lo:hi].tolist()))
        assert got == exp, p


def test_sada_single_suffix_range(fixture):
    docs, coll, data = fixture
    s = build_sada(data, "plain")
    # size-1 ranges are trivially node-aligned (leaves): df = 1
    for lo in range(0, coll.n, 7):
        assert int(sada_count(s, lo, lo + 1)) == 1


def test_h_total_is_occ_minus_df_at_root(fixture):
    docs, coll, data = fixture
    H = compute_h_slots(data)
    d_distinct = len(set(data.da.tolist()))
    assert H.sum() == coll.n - d_distinct


def test_runs_shrink_on_repetitive():
    """Section 5.3: H' runs stay near-linear in base length, sublinear in
    collection size, for copy+mutate collections."""
    base = "".join(RNG.choice(list("acgt"), 100))

    def runs_for(d, muts):
        docs = []
        for _ in range(d):
            b = list(base)
            for _ in range(muts):
                b[RNG.integers(0, len(b))] = RNG.choice(list("acgt"))
            docs.append("".join(b))
        coll = concat_documents(docs)
        data = build_suffix_data(coll)
        return hprime_runs_of_ones(data), coll.n

    r_small, n_small = runs_for(5, 1)
    r_big, n_big = runs_for(20, 1)
    # quadrupling the collection must not quadruple the runs
    assert r_big < 2.5 * r_small, (r_small, r_big)
    assert r_big < n_big / 2


def test_modeled_sizes_ordering():
    docs = _versions(12, 80, 1)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    sizes = {v: build_sada(data, v).modeled_bits() for v in VARIANTS}
    # on repetitive data the compressed variants beat plain
    assert sizes["rle"] < sizes["plain"]
    assert sizes["sparse"] < sizes["plain"]


def test_sada_agrees_with_ilcp_counting():
    from repro.core.ilcp import build_ilcp, ilcp_count_docs

    docs = _versions(6, 35, 2)
    coll = concat_documents(docs)
    data = build_suffix_data(coll)
    s = build_sada(data, "sparse")
    ilcp = build_ilcp(data)
    pats = {doc[i : i + m] for doc in docs for m in (1, 2, 3) for i in range(0, 10)}
    for p in sorted(pats):
        enc = encode_pattern(p)
        lo, hi = sa_range_for_pattern(data, enc)
        if lo >= hi:
            continue
        a = int(sada_count(s, lo, hi))
        b = int(ilcp_count_docs(ilcp, lo, hi, len(enc)))
        assert a == b, p
