"""Property-based index-integrity tests: any single corruption of an index
pytree must be caught by the structural validators (or, for corruptions
that happen to preserve every invariant, by the checksum fingerprints).

Uses hypothesis (the real package, or the seeded shim in tests/_stubs) to
draw corruption sites; every drawn mutation of a freshly built service
must raise :class:`repro.errors.IndexIntegrityError`.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common import replace
from repro.data.collections import SyntheticSpec, generate
from repro.errors import IndexIntegrityError
from repro.serve.retrieval import RetrievalService
from repro.serve.validate import (
    checksum_pytree,
    fingerprint_service,
    validate_csa,
    validate_ilcp,
    validate_pdl,
    validate_sada,
    validate_service,
    verify_fingerprints,
    wm_symbol_histogram,
)


@pytest.fixture(scope="module")
def svc():
    coll = generate(SyntheticSpec("version", n_base=2, n_variants=6,
                                  base_len=90, mutation_rate=0.01, seed=7))
    return RetrievalService.build(coll, block_size=16, beta=8.0)


def _mut(arr, idx, val):
    out = np.array(arr, copy=True)
    out[idx] = val
    return out


# ---------------------------------------------------------------------------
# Happy path
# ---------------------------------------------------------------------------


def test_fresh_build_validates_and_fingerprints(svc):
    fps = validate_service(svc)
    assert fps == fingerprint_service(svc) == svc.fingerprints
    assert sorted(fps) == ["csa", "da", "ilcp", "pdl_list", "pdl_topk", "sada"]
    verify_fingerprints(svc, fps)        # no exception on intact indexes


def test_wm_histogram_matches_c_array(svc):
    hist = wm_symbol_histogram(svc.csa.wm)
    assert np.array_equal(hist, np.diff(np.asarray(svc.csa.counts)))
    assert int(hist.sum()) == svc.csa.n


# ---------------------------------------------------------------------------
# Single-bit corruption of the wavelet matrix is always caught
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_any_wm_bit_flip_is_caught(svc, data):
    wm = svc.csa.wm
    words = np.array(wm.words, copy=True)
    lvl = data.draw(st.integers(0, wm.levels - 1))
    bit = data.draw(st.integers(0, words.shape[1] * 32 - 1))
    words[lvl, bit // 32] ^= np.uint32(1) << np.uint32(bit % 32)
    bad = replace(svc.csa, wm=replace(wm, words=words))
    with pytest.raises(IndexIntegrityError):
        validate_csa(bad)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_wm_metadata_corruption_is_caught(svc, data):
    wm = svc.csa.wm
    field, idx_max = data.draw(st.sampled_from([
        ("zcount", wm.levels - 1),
        ("ones_prefix", None),
        ("sym_starts", wm.sigma - 1),
    ]))
    delta = data.draw(st.sampled_from([-2, -1, 1, 3]))
    if field == "ones_prefix":
        prefix = np.array(wm.ones_prefix, copy=True)
        lvl = data.draw(st.integers(0, wm.levels - 1))
        word = data.draw(st.integers(1, prefix.shape[1] - 1))
        prefix[lvl, word] += delta
        bad = replace(wm, ones_prefix=prefix)
    else:
        idx = data.draw(st.integers(0, idx_max))
        bad = replace(wm, **{field: _mut(getattr(wm, field), idx,
                                         int(np.asarray(getattr(wm, field))[idx])
                                         + delta)})
    with pytest.raises(IndexIntegrityError):
        validate_csa(replace(svc.csa, wm=bad))


# ---------------------------------------------------------------------------
# CSA / ILCP / PDL / Sada structural mutations
# ---------------------------------------------------------------------------


def test_csa_c_array_corruptions(svc):
    counts = np.asarray(svc.csa.counts)
    for bad_counts in (
        _mut(counts, 0, 1),                        # C[0] != 0
        _mut(counts, 1, svc.csa.d + 1),            # C[1] != d
        _mut(counts, len(counts) - 1, svc.csa.n + 1),   # C[sigma] > n
        counts[:-1],                               # wrong length
    ):
        with pytest.raises(IndexIntegrityError):
            validate_csa(replace(svc.csa, counts=bad_counts))


def test_csa_sample_out_of_range(svc):
    samples = _mut(svc.csa.samples, 0, svc.csa.n)
    with pytest.raises(IndexIntegrityError, match="SA sample"):
        validate_csa(replace(svc.csa, samples=samples))


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_ilcp_mutations_are_caught(svc, data):
    ilcp = svc.ilcp
    assert ilcp.nruns >= 2, "fixture collection too degenerate"
    which = data.draw(st.sampled_from(
        ["bounds", "maximality", "clens", "vro"]
    ))
    if which == "bounds":
        idx = data.draw(st.integers(1, ilcp.nruns - 1))
        rs = np.asarray(ilcp.run_starts)
        bad = replace(ilcp, run_starts=_mut(rs, idx, int(rs[idx - 1])))
    elif which == "maximality":
        idx = data.draw(st.integers(1, ilcp.nruns - 1))
        v = np.asarray(ilcp.vilcp)
        bad = replace(ilcp, vilcp=_mut(v, idx, int(v[idx - 1])))
    elif which == "clens":
        idx = data.draw(st.integers(1, ilcp.nruns - 1))
        cl = np.asarray(ilcp.clens)
        bad = replace(ilcp, clens=_mut(cl, idx, int(cl[idx - 1])))
    else:
        vro = np.asarray(ilcp.value_run_offset)
        bad = replace(ilcp, value_run_offset=_mut(vro, len(vro) - 1,
                                                  ilcp.nruns + 1))
    with pytest.raises(IndexIntegrityError):
        validate_ilcp(bad)


def test_pdl_mutations_are_caught(svc):
    pdl = svc.pdl_list
    soff = np.asarray(pdl.set_off)
    with pytest.raises(IndexIntegrityError, match="set_off"):
        validate_pdl(replace(pdl, set_off=_mut(soff, len(soff) - 1,
                                               int(soff[-1]) + 7)))
    leaf = np.asarray(pdl.leaf_starts)
    with pytest.raises(IndexIntegrityError):
        validate_pdl(replace(pdl, leaf_starts=_mut(leaf, 0, 1)))
    A = np.asarray(pdl.A)
    if A.size:
        with pytest.raises(IndexIntegrityError, match="grammar symbol"):
            validate_pdl(replace(pdl, A=_mut(A, 0, pdl.d + pdl.nrules + 5)))


def test_sada_slot_count_mismatch(svc):
    with pytest.raises(IndexIntegrityError, match="num_slots"):
        validate_sada(replace(svc.sada, num_slots=svc.sada.num_slots + 1))


# ---------------------------------------------------------------------------
# Fingerprints catch bit-level corruption that keeps the invariants
# ---------------------------------------------------------------------------


def test_fingerprint_catches_invariant_preserving_corruption(svc):
    # swapping two equal-length runs' *sample values* keeps every structural
    # invariant candidate simple: just flip one DA entry to another valid id
    da = np.asarray(svc.da)
    bad_da = _mut(da, 0, (int(da[0]) + 1) % svc.coll.d)
    bad = replace(svc, da=bad_da)
    validate_service(bad)                 # structurally still fine
    assert checksum_pytree(bad_da) != checksum_pytree(da)
    with pytest.raises(IndexIntegrityError, match="checksum mismatch"):
        verify_fingerprints(bad, svc.fingerprints)


def test_build_time_validation_is_wired_in(svc):
    # build(validate=True) already ran: fingerprints stored on the service
    assert svc.fingerprints and verify_fingerprints(svc, svc.fingerprints) is None
