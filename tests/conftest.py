"""Test bootstrap: gate optional third-party test deps.

The property-based suites use ``hypothesis``; this container image does not
ship it and nothing may be pip-installed here.  When the real package is
absent, a minimal API-compatible shim (tests/_stubs/hypothesis) is put on
sys.path so the suites still collect and run as seeded randomized tests.
With hypothesis installed (e.g. in CI) the shim is never imported.
"""

import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))
