"""Test bootstrap: gate optional third-party test deps + compile-cache
hygiene.

The property-based suites use ``hypothesis``; this container image does not
ship it and nothing may be pip-installed here.  When the real package is
absent, a minimal API-compatible shim (tests/_stubs/hypothesis) is put on
sys.path so the suites still collect and run as seeded randomized tests.
With hypothesis installed (e.g. in CI) the shim is never imported.

The full suite compiles several hundred XLA programs in one process; on
single-core CPU runners the accumulated executables eventually crash the
native compiler (segfault inside ``backend_compile`` on the next large
vmapped while-loop program).  Dropping jax's program caches between test
modules keeps the JIT arena bounded; within a module, caches (and
therefore compile counts asserted by the serving tests) are untouched.

Host-device virtualization: the docs-mesh sharding tests need several
devices, and ``--xla_force_host_platform_device_count`` only takes effect
if it is in ``XLA_FLAGS`` before the first jax import — so it is injected
here, at the top of conftest, unless the environment already forces a
count of its own.
"""

import importlib.util
import os
import sys

_FORCE_DEVICES = "--xla_force_host_platform_device_count"
if _FORCE_DEVICES not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{_FORCE_DEVICES}=8 " + os.environ.get("XLA_FLAGS", "")
    ).strip()

import jax  # noqa: E402  (XLA_FLAGS must be set first)
import pytest  # noqa: E402

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
