"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any JAX
initialization, and nothing here may run earlier.

Topology: TPU v5e pods of 256 chips as a (data=16, model=16) torus slice;
the multi-pod mesh adds a leading pod axis (pod=2) for 512 chips, used by
data parallelism's hierarchical gradient reduction (reduce-scatter inside
the pod over ICI, cross-pod all-reduce over DCI, all-gather inside).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests / examples on this container."""
    return jax.make_mesh((1, 1), ("data", "model"))
