"""Retrieval serving launcher.

    PYTHONPATH=src python -m repro.launch.serve [--corpus version-p001]
        [--queries 256] [--k 10] [--mode topk|list|count|tfidf]
        [--deadline-ms 500] [--inject executor_fail:0.1,slow_pdl]

Builds the full paper index stack over a synthetic corpus (see
repro.data.collections for the families) and serves batched queries
through the resilient runtime (``repro.serve.runtime``: deadlines,
retry/breaker, graceful degradation) — the single-host analogue of the
production retrieval tier (the index structures are per-shard state in a
real deployment; the query engine is identical).

Latency accounting is split honestly: the first execution of each
(endpoint, shape bucket) pays the AOT compile and is reported on its own
line; the percentiles below cover steady-state batches only.  Earlier
versions of this launcher mixed the two, which made p99 a compile
benchmark.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data.collections import (
    generate,
    paperlike_collections,
    random_substring_patterns,
)
from repro.serve import faults
from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import RuntimeConfig, ServeRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="version-p001",
                    choices=list(paperlike_collections()))
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mode", default="topk",
                    choices=["topk", "list", "count", "tfidf"])
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="per-request deadline enforced by the runtime")
    ap.add_argument("--inject", default=None,
                    help="fault specs, e.g. 'executor_fail:0.1,slow_pdl' "
                         "(see repro.serve.faults.NAMED_FAULTS)")
    args = ap.parse_args()

    spec = paperlike_collections()[args.corpus]
    coll = generate(spec)
    t0 = time.time()
    svc = RetrievalService.build(coll, block_size=64, beta=16.0)
    print(f"corpus {args.corpus}: n={coll.n} d={coll.d}; "
          f"index built in {time.time()-t0:.1f}s (integrity validated: "
          f"{', '.join(sorted(svc.fingerprints))})")
    for k, v in svc.space_report().items():
        print(f"  {k:22s} {v if isinstance(v, int) else round(v, 3)}")

    workload = random_substring_patterns(coll, 2000, 6, 128)
    rng = np.random.default_rng(0)
    rt = ServeRuntime(svc, RuntimeConfig(
        max_batch=args.batch, k=args.k,
        max_df=min(256, coll.d + 1),
        default_deadline_s=args.deadline_ms / 1e3,
    ))

    def payload(i: int):
        if args.mode == "tfidf":
            j = rng.integers(0, len(workload))
            return [workload[i], workload[int(j)]]
        return workload[i]

    # warm pass: compiles the (mode, bucket) program and settles the
    # grow-only brute windows outside the timed (and deadlined) loop
    for _ in range(2):
        rt.serve([(args.mode, payload(int(i)))
                  for i in rng.integers(0, len(workload), args.batch)],
                 deadline_s=1e9)

    specs = faults.parse_fault_specs(args.inject) if args.inject else []
    lat = []
    served = 0
    with faults.inject(*specs):
        while served < args.queries:
            idx = rng.integers(0, len(workload), args.batch)
            t0 = time.perf_counter()
            rt.serve([(args.mode, payload(int(i))) for i in idx])
            lat.append(time.perf_counter() - t0)
            served += len(idx)
    m = rt.metrics
    ms = np.asarray(lat) * 1e3
    compiles = ", ".join(f"{k}={v}s" for k, v in m.as_dict()["compile_s"].items())
    print(f"compile (first batch per bucket, excluded below): {compiles}")
    print(f"{args.mode}: {served} queries, batch={args.batch}: "
          f"steady p50={np.percentile(ms,50):.1f}ms "
          f"p99={np.percentile(ms,99):.1f}ms ({served/ms.sum()*1e3:.0f} q/s)")
    print(f"resilience: degraded_fraction={m.degraded_fraction:.3f} "
          f"deadline_miss_rate={m.deadline_miss_rate:.3f} "
          f"retries={m.retries} breaker_trips={m.breaker_trips} "
          f"reasons={dict(m.degrade_reasons)}")


if __name__ == "__main__":
    main()
