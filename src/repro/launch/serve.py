"""Retrieval serving launcher.

    PYTHONPATH=src python -m repro.launch.serve [--corpus version-p001]
        [--queries 256] [--k 10] [--mode topk|list|count|tfidf]

Builds the full paper index stack over a synthetic corpus (see
repro.data.collections for the families) and serves batched queries with
latency percentiles — the single-host analogue of the production retrieval
tier (the index structures are per-shard state in a real deployment; the
query engine is identical).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data.collections import (
    generate,
    paperlike_collections,
    random_substring_patterns,
)
from repro.serve.retrieval import RetrievalService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="version-p001",
                    choices=list(paperlike_collections()))
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mode", default="topk",
                    choices=["topk", "list", "count", "tfidf"])
    args = ap.parse_args()

    spec = paperlike_collections()[args.corpus]
    coll = generate(spec)
    t0 = time.time()
    svc = RetrievalService.build(coll, block_size=64, beta=16.0)
    print(f"corpus {args.corpus}: n={coll.n} d={coll.d}; "
          f"index built in {time.time()-t0:.1f}s")
    for k, v in svc.space_report().items():
        print(f"  {k:22s} {v if isinstance(v, int) else round(v, 3)}")

    workload = random_substring_patterns(coll, 2000, 6, 128)
    rng = np.random.default_rng(0)
    lat = []
    served = 0
    while served < args.queries:
        batch = [workload[i] for i in rng.integers(0, len(workload), args.batch)]
        t0 = time.perf_counter()
        if args.mode == "count":
            svc.count(batch)
        elif args.mode == "list":
            svc.list_docs(batch, max_df=min(256, coll.d + 1))
        elif args.mode == "tfidf":
            svc.tfidf([batch[i : i + 2] for i in range(0, len(batch), 2)],
                      k=args.k)
        else:
            svc.topk(batch, k=args.k)
        lat.append(time.perf_counter() - t0)
        served += len(batch)
    ms = np.asarray(lat) * 1e3
    print(f"{args.mode}: {served} queries, batch={args.batch}: "
          f"p50={np.percentile(ms,50):.1f}ms p99={np.percentile(ms,99):.1f}ms "
          f"({served/ms.sum()*1e3:.0f} q/s)")


if __name__ == "__main__":
    main()
