import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out experiments/dryrun.json]

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
            .lower(*input_specs(arch))
        compiled = lowered.compile()
        memory_analysis / cost_analysis / HLO collective parse

Success proves the sharding config is coherent: every parameter, optimizer
moment, batch, and KV-cache dimension divides (or GSPMD-pads) over the
(data, model) and (pod, data, model) meshes, and the per-device memory fits
a 16 GB v5e chip.  The first two lines of this file pin the host platform
to 512 fake devices BEFORE any jax import, as required.
"""

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.registry import ARCH_SHAPES, ALL_ARCHS, build_cell
from repro.dist.roofline import parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh


def _to_shardings(mesh, spec_tree, abstract_tree):
    def conv(spec, _ab):
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        conv, spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cell = build_cell(arch, shape, mesh)
    in_sh = _to_shardings(mesh, cell.in_specs, cell.abstract_args)
    out_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        cell.out_specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )

    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*cell.abstract_args)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per partition
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, scan_trips=cell.meta.get("scan_trips", 1))
    rl = roofline_terms(
        cell.meta, chips, coll.total_bytes,
        raw_flops=float(cost.get("flops", 0.0)),
        raw_bytes=float(cost.get("bytes accessed", 0.0)),
    )

    def _mb(x):
        return None if x is None else round(x / 2**20, 2)

    # Analytic per-device memory model (TPU-side estimate).  The CPU
    # backend's memory_analysis over-reports for two reasons recorded in
    # EXPERIMENTS.md: (a) its float-support pass materializes f32 copies of
    # every bf16 dot operand/result (TPU MXUs consume bf16 natively), and
    # (b) its buffer assignment follows a throughput-oriented parallel
    # schedule rather than a memory-minimizing one.
    meta = cell.meta
    state_bytes = 0
    for tree, specs in zip(cell.abstract_args, cell.in_specs):
        for ab, spec in zip(
            jax.tree.leaves(tree),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec)),
        ):
            shard = 1
            for entry in (spec or ()):  # PartitionSpec iterates entries
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    shard *= mesh.shape[ax]
            import numpy as _np
            state_bytes += int(_np.prod(ab.shape)) * ab.dtype.itemsize // max(shard, 1)
    analytic_act = meta.get("analytic_bytes", 0) / chips * 0.15  # live window
    analytic_dev_mb = (state_bytes + analytic_act) / 2**20

    result = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": {
            "args_mb": _mb(getattr(mem, "argument_size_in_bytes", None)),
            "out_mb": _mb(getattr(mem, "output_size_in_bytes", None)),
            "temp_mb": _mb(getattr(mem, "temp_size_in_bytes", None)),
            "code_mb": _mb(getattr(mem, "generated_code_size_in_bytes", None)),
            "analytic_state_mb": round(state_bytes / 2**20, 1),
            "analytic_device_mb": round(analytic_dev_mb, 1),
        },
        "collectives": {k: round(v / 2**20, 3) for k, v in coll.by_kind.items()},
        "collective_count": coll.count,
        "roofline": rl.row(),
        "meta": {
            k: v for k, v in cell.meta.items()
            if k in ("params_total", "params_active", "tokens", "scan_trips")
        },
    }
    if verbose:
        dom = rl.dominant
        print(
            f"[OK] {arch:28s} {shape:14s} {result['mesh']:10s} "
            f"compile={compile_s:6.1f}s temp={result['memory']['temp_mb']}MB "
            f"dom={dom} c/m/x = {rl.compute_s:.2e}/{rl.memory_s:.2e}/"
            f"{rl.collective_s:.2e}s"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        shapes = [args.shape] if args.shape else list(ARCH_SHAPES[arch])
        for shape in shapes:
            for multi in meshes:
                try:
                    results.append(run_cell(arch, shape, multi))
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append(
                        {"arch": arch, "shape": shape, "multi": multi,
                         "error": f"{type(e).__name__}: {e}"}
                    )
                    print(f"[FAIL] {arch} {shape} multi={multi}: {e}")
                    traceback.print_exc(limit=3)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failures -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
