"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        [--steps 100] [--reduced] [--ckpt DIR] [--compress-grads]

On this host the reduced configs run end-to-end (full configs need the
production mesh; see launch.dryrun for the 512-device lowering).  The loop
is the fault-tolerant production loop: resume-from-checkpoint, periodic
atomic saves, straggler accounting, optional int8 EF gradient compression.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, get_arch_module
from repro.data.pipelines import lm_batches, random_graph, recsys_batches
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    mod = get_arch_module(args.arch)
    cfg = mod.reduced_config()
    family = mod.FAMILY

    if family == "lm":
        from repro.models.transformer import forward_train, init_params

        it = lm_batches(cfg.vocab, args.batch, args.seq)

        def batch_fn(step):
            b = next(it)
            return {k: jnp.asarray(v) for k, v in b.items()}

        def loss_fn(params, batch):
            return forward_train(cfg, params, batch["tokens"], batch["labels"])

        init_fn = lambda: init_params(cfg, jax.random.PRNGKey(0))

    elif family == "gnn":
        from repro.models.nequip import forward_train as gnn_loss, init_params as gnn_init

        g = random_graph(64, 256, cfg.d_feat_in, n_graphs=4)

        def batch_fn(step):
            return {k: jnp.asarray(v) for k, v in g.items()}

        def loss_fn(params, batch):
            return gnn_loss(cfg, params, batch, 4)

        init_fn = lambda: gnn_init(cfg, jax.random.PRNGKey(0))

    else:
        from repro.models import recsys as R

        init, loss = {
            "fm": (R.fm_init, R.fm_train_loss),
            "sasrec": (R.sasrec_init, R.sasrec_train_loss),
            "autoint": (R.autoint_init, R.autoint_train_loss),
            "dlrm-mlperf": (R.dlrm_init, R.dlrm_train_loss),
        }[args.arch]
        if args.arch == "sasrec":
            it = recsys_batches((), args.batch, seq_len=cfg.seq_len,
                                n_items=cfg.n_items)
        else:
            it = recsys_batches(
                cfg.vocab_sizes, args.batch,
                n_dense=getattr(cfg, "n_dense", 0),
            )

        def batch_fn(step):
            return {k: jnp.asarray(v) for k, v in next(it).items()}

        def loss_fn(params, batch):
            return loss(cfg, params, batch)

        init_fn = lambda: init(cfg, jax.random.PRNGKey(0))

    res = train(
        loss_fn, init_fn, batch_fn,
        n_steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        opt_cfg=AdamWConfig(lr=args.lr),
        compress_grads=args.compress_grads,
    )
    w = min(10, len(res.losses) // 2) or 1
    print(
        f"[{args.arch}] steps={res.final_step} "
        f"loss {np.mean(res.losses[:w]):.4f} -> {np.mean(res.losses[-w:]):.4f} "
        f"restarts={res.restarts} stragglers={res.straggler_steps}"
    )


if __name__ == "__main__":
    main()
