"""Document-collection generators mirroring Section 6.1.1.

Synthetic families (all parameters as in the paper, scaled by ``scale``):

* DNA       — like Influenza: d_base base documents over {a,c,g,t}; base
              docs are mutations (rate 10p) of a prefix of a seed sequence;
              each base doc gets n_variants variants at rate p.
* Concat    — like Page: all variants of one base document concatenated
              into a single document.
* Version   — like Revision: every variant is its own document.

Plus pattern-workload generators following Section 6.1.2 (random substrings
filtered by occ/df ratio, word-like terms).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.suffix import Collection, concat_documents
from repro.errors import InvalidQueryError

DNA = "acgt"


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    family: str            # dna | concat | version
    n_base: int
    n_variants: int        # per base document
    base_len: int
    mutation_rate: float
    sigma: str = DNA
    seed: int = 0


def _mutate(rng, doc: np.ndarray, rate: float, alphabet_size: int) -> np.ndarray:
    out = doc.copy()
    mask = rng.random(len(doc)) < rate
    out[mask] = rng.integers(0, alphabet_size, mask.sum())
    return out


def generate(spec: SyntheticSpec) -> Collection:
    rng = np.random.default_rng(spec.seed)
    sigma = len(spec.sigma)
    seed_seq = rng.integers(0, sigma, spec.base_len)
    bases = [
        _mutate(rng, seed_seq, 10 * spec.mutation_rate, sigma)
        for _ in range(spec.n_base)
    ]
    variants_per_base = [
        [_mutate(rng, base, spec.mutation_rate, sigma) for _ in range(spec.n_variants)]
        for base in bases
    ]
    if spec.family == "concat":
        docs = [np.concatenate(vs) for vs in variants_per_base]
    else:  # dna / version: each variant is a document
        docs = [v for vs in variants_per_base for v in vs]
    return concat_documents(docs)


def paperlike_collections(scale: float = 1.0, seed: int = 0):
    """A set of collections spanning the paper's repetitiveness regimes."""
    s = lambda x: max(2, int(x * scale))
    return {
        "dna-p001": SyntheticSpec("dna", n_base=1, n_variants=s(100), base_len=s(1000),
                                  mutation_rate=0.001, seed=seed),
        "dna-p03": SyntheticSpec("dna", n_base=1, n_variants=s(100), base_len=s(1000),
                                 mutation_rate=0.03, seed=seed),
        "version-p001": SyntheticSpec("version", n_base=s(10), n_variants=s(10),
                                      base_len=s(1000), mutation_rate=0.001, seed=seed),
        "version-p01": SyntheticSpec("version", n_base=s(10), n_variants=s(10),
                                     base_len=s(1000), mutation_rate=0.01, seed=seed),
        "concat-p003": SyntheticSpec("concat", n_base=s(10), n_variants=s(10),
                                     base_len=s(1000), mutation_rate=0.003, seed=seed),
        "random": SyntheticSpec("version", n_base=s(100), n_variants=1,
                                base_len=s(1000), mutation_rate=1.0, seed=seed),
    }


# ---------------------------------------------------------------------------
# Query workloads (Section 6.1.2)
# ---------------------------------------------------------------------------


def random_substring_patterns(
    coll: Collection, n_extract: int, length: int, keep: int, seed: int = 1,
    by_occ_df_ratio: bool = True,
):
    """Extract random substrings, dedupe, keep those with largest occ/df —
    the paper's Influenza/Swissprot/DNA workload construction."""
    from repro.core.suffix import build_suffix_data, sa_range_for_pattern

    rng = np.random.default_rng(seed)
    text = coll.text
    n = coll.n
    cands = set()
    for _ in range(n_extract):
        p = int(rng.integers(0, max(1, n - length)))
        sub = text[p : p + length]
        if (sub == 0).any():
            continue
        cands.add(tuple(int(x) for x in sub))
    cands = sorted(cands)
    if not by_occ_df_ratio or not cands:
        return [np.asarray(c, dtype=np.int32) for c in cands[:keep]]

    data = build_suffix_data(coll)
    scored = []
    for c in cands:
        pat = np.asarray(c, dtype=np.int32)
        lo, hi = sa_range_for_pattern(data, pat)
        occ = hi - lo
        if occ == 0:
            continue
        df = len(set(data.da[lo:hi].tolist()))
        scored.append((occ / df, pat))
    scored.sort(key=lambda t: -t[0])
    return [pat for _, pat in scored[:keep]]


def normalize_patterns(patterns, *, sigma: int | None = None,
                       max_len: int | None = None):
    """The single input-hardening gate for every query endpoint.

    Replaces the ad-hoc checks that used to live in ``serve.retrieval`` and
    ``core.csa``: every pattern becomes a 1-D int32 array, and the contract
    splits cleanly in two:

    * **structurally bad** input — ``None``, floats, nested/2-D payloads,
      arbitrary objects — raises :class:`repro.errors.InvalidQueryError`
      at admission time (a request, not a pattern);
    * **soft-invalid** input — empty patterns, patterns longer than
      ``max_len`` (the largest serving length bucket), symbols outside
      ``[0, sigma)`` — normalizes to a zero-length pattern, which flows
      through the engines as an empty SA range and reports empty/zero
      results.  Never a trace error, never an out-of-bounds gather.

    ``str``/``bytes`` patterns are mapped byte-wise to ``[1, 256]``, the
    same convention ``concat_documents`` applies to string documents.
    Returns a list of 1-D ``np.int32`` arrays of the same length as
    ``patterns``.
    """
    _empty = np.zeros(0, np.int32)
    out = []
    for i, p in enumerate(patterns):
        if isinstance(p, str):
            a = np.frombuffer(p.encode("utf-8"), dtype=np.uint8).astype(np.int32) + 1
        elif isinstance(p, (bytes, bytearray)):
            a = np.frombuffer(bytes(p), dtype=np.uint8).astype(np.int32) + 1
        else:
            try:
                a = np.asarray(p)
            except Exception as e:
                raise InvalidQueryError(
                    f"pattern {i}: not convertible to an array ({type(p).__name__})"
                ) from e
            if a.ndim != 1:
                raise InvalidQueryError(
                    f"pattern {i}: expected a 1-D symbol sequence, got shape"
                    f" {a.shape}"
                )
            if a.size and a.dtype.kind not in "iu":
                raise InvalidQueryError(
                    f"pattern {i}: expected integer symbols or str, got dtype"
                    f" {a.dtype}"
                )
            a = a.astype(np.int32, copy=False)
        if max_len is not None and a.size > max_len:
            a = _empty          # longer than any length bucket: cannot serve
        elif sigma is not None and a.size and (
            (a < 0).any() or (a >= sigma).any()
        ):
            a = _empty          # out-of-alphabet symbol: zero occurrences
        out.append(a)
    return out


def pad_patterns(patterns, max_m: int | None = None):
    """Pad to a dense [Q, max_m] batch + lengths (the serving layout)."""
    if not patterns:
        return np.zeros((0, 1), np.int32), np.zeros(0, np.int32)
    max_m = max_m or max(len(p) for p in patterns)
    out = np.zeros((len(patterns), max_m), np.int32)
    lens = np.zeros(len(patterns), np.int32)
    for i, p in enumerate(patterns):
        out[i, : len(p)] = p[:max_m]
        lens[i] = min(len(p), max_m)
    return out, lens
