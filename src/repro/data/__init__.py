"""Data pipelines: the paper's document collections (real-life analogue
generators + the synthetic DNA/Concat/Version families of Section 6.1.1),
query workloads (Section 6.1.2), LM token batches, graph sampling, and
Criteo-like recsys batches."""
