"""Batch pipelines for the three architecture families.

* LM: synthetic token streams (optionally sourced from a document
  collection's symbol stream, tying the paper's corpora to LM training),
  with a double-buffered host prefetcher.
* GNN: random graph generation with the exact dry-run shapes, plus a REAL
  layered neighbor sampler (fanout 15-10) as the assignment requires.
* RecSys: Criteo-like click batches with skewed categorical draws.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0, text=None):
    """Infinite token-batch generator.  With ``text`` (an int array, e.g. a
    repro Collection's symbol stream), batches are sliced from the corpus;
    otherwise Zipf-ish random tokens."""
    rng = np.random.default_rng(seed)
    if text is not None:
        text = np.asarray(text) % vocab
    while True:
        if text is not None and len(text) > seq + 1:
            starts = rng.integers(0, len(text) - seq - 1, batch)
            tokens = np.stack([text[s : s + seq] for s in starts])
        else:
            tokens = rng.zipf(1.3, (batch, seq)).clip(0, vocab - 1)
        yield {"tokens": tokens.astype(np.int32), "labels": tokens.astype(np.int32)}


class Prefetcher:
    """Double-buffered host-side prefetch (overlaps batch assembly with the
    device step — the standard input-pipeline overlap trick)."""

    def __init__(self, it, depth: int = 2):
        self.q = queue.Queue(maxsize=depth)
        self.it = it
        self.done = False
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def _fill(self):
        for item in self.it:
            self.q.put(item)
            if self.done:
                return

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self.done = True


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_graphs: int = 1,
                 seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    return {
        "node_feat": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "edge_index": np.stack([src, dst]).astype(np.int32),
        "edge_vec": (rng.standard_normal((n_edges, 3)) * 2).astype(np.float32),
        "graph_id": np.sort(rng.integers(0, n_graphs, n_nodes)).astype(np.int32),
        "energy": rng.standard_normal(n_graphs).astype(np.float32),
    }


def build_csr(n_nodes: int, edge_index: np.ndarray):
    """CSR adjacency for sampling: (indptr, neighbors)."""
    src, dst = edge_index
    order = np.argsort(dst, kind="stable")
    neighbors = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, neighbors


def neighbor_sample(indptr, neighbors, seeds: np.ndarray, fanouts=(15, 10),
                    seed: int = 0):
    """Layered fanout sampling (GraphSAGE-style).  Returns a padded
    subgraph: (nodes, edge_index local ids, layer offsets).

    For each layer, every frontier node draws ``fanout`` neighbors with
    replacement (isolated nodes draw self-loops) — fixed-shape output, the
    TPU-friendly regime.
    """
    rng = np.random.default_rng(seed)
    id_of = {int(v): i for i, v in enumerate(np.asarray(seeds))}
    all_nodes = [int(v) for v in np.asarray(seeds)]
    edges_src, edges_dst = [], []
    frontier = list(all_nodes)

    for fanout in fanouts:
        discovered = []
        for v in frontier:
            lo, hi = int(indptr[v]), int(indptr[v + 1])
            if hi > lo:
                picks = neighbors[rng.integers(lo, hi, fanout)]
            else:
                picks = np.full(fanout, v)  # isolated: self-loops
            for u in picks:
                u = int(u)
                if u not in id_of:
                    id_of[u] = len(all_nodes)
                    all_nodes.append(u)
                    discovered.append(u)
                edges_src.append(id_of[u])
                edges_dst.append(id_of[v])
        frontier = discovered
    edge_index = np.stack([np.asarray(edges_src), np.asarray(edges_dst)]).astype(
        np.int32
    )
    return np.asarray(all_nodes, dtype=np.int64), edge_index


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------


def recsys_batches(vocab_sizes, batch: int, n_dense: int = 0, seq_len: int = 0,
                   n_items: int = 0, seed: int = 0):
    """Criteo-like batches: Zipf-skewed categorical ids, normal dense
    features, clicks with ~25% positive rate.  seq_len/n_items > 0 emits
    SASRec-style sequence batches instead."""
    rng = np.random.default_rng(seed)
    while True:
        if seq_len:
            seq = rng.zipf(1.2, (batch, seq_len)).clip(1, n_items - 1)
            pos = rng.zipf(1.2, (batch, seq_len)).clip(1, n_items - 1)
            neg = rng.integers(1, n_items, (batch, seq_len))
            yield {
                "item_seq": seq.astype(np.int32),
                "pos_items": pos.astype(np.int32),
                "neg_items": neg.astype(np.int32),
            }
            continue
        sparse = np.stack(
            [rng.zipf(1.2, batch).clip(1, v) - 1 for v in vocab_sizes], axis=1
        )
        out = {
            "sparse": sparse.astype(np.int32),
            "label": (rng.random(batch) < 0.25).astype(np.float32),
        }
        if n_dense:
            out["dense"] = rng.standard_normal((batch, n_dense)).astype(np.float32)
        yield out
