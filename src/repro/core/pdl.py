"""PDL — Precomputed Document Lists (Section 4).

Build (host-side, offline):
  1. Enumerate suffix-tree topology (lcp-interval tree).
  2. Select *leaf blocks*: nodes v with |SA_v| <= b < |SA_parent(v)| — these
     tile the suffix array left to right (suffix-tree leaves whose smallest
     enclosing interval exceeds b become single-position blocks).
  3. Bottom-up beta-pruning of internal nodes: keep v iff the total size of
     its current children's sets exceeds beta * |D_v| (storing v then caps
     the union work for queries at beta * df, Section 4.1 condition 3);
     with beta=None every internal node above the leaf blocks is kept
     (the paper's PDL-b "inverted index" variant for top-k).
  4. Document lists: listing mode stores D_v sorted by id; top-k mode sorts
     by (tf desc, id asc) and stores run-length-encoded frequencies
     (Section 4.2).
  5. All lists are Re-Pair-compressed with a shared grammar
     (repro.grammar.repair); stored sets hold terminals (< d) and
     nonterminals, exactly the paper's A / G arrays.

Query (jit/vmap, TPU execution model):
  * partial head/tail blocks -> brute CSA windows (the paper's list());
  * full blocks -> the Fig-4 climb: from each leaf, follow first-child
    parent pointers to the highest stored node whose subtree fits in the
    query, decompress its set (bounded-stack grammar expansion), jump to
    the leaf after that subtree;
  * listing: dedupe via sort-unique; top-k: merge by document, sum term
    frequencies, rank by (tf desc, id asc) — the "brute-force merging" the
    paper found fastest (Section 4.2).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, ceil_log2, delta_code_len, elias_fano_bits, pytree_dataclass
from repro.core.csa import CSA, csa_lookup_batch
from repro.core.listing import _distinct_from_window
from repro.core.sufftree import lcp_interval_tree
from repro.core.suffix import SuffixData
from repro.grammar.repair import repair_compress_lists


@pytree_dataclass(
    meta=(
        "n", "d", "L", "I", "block_size", "beta", "nrules",
        "max_set_len", "max_rule_depth", "has_freqs", "total_docs_stored",
    )
)
class PDLIndex:
    # --- leaf tiling ---------------------------------------------------
    leaf_starts: jnp.ndarray     # int32[L + 1] SA offsets; leaf_starts[L] = n
    # --- sparse tree (nodes: 0..L-1 leaves, L..L+I-1 internal) ----------
    is_first_child: jnp.ndarray  # bool[L + I]
    parent_of: jnp.ndarray       # int32[L + I]: internal idx for first children, else -1
    next_leaf: jnp.ndarray       # int32[max(I,1)]: leaf idx after internal subtree
    # --- stored (reduced) document lists --------------------------------
    set_off: jnp.ndarray         # int32[L + I + 1] into A
    A: jnp.ndarray               # int32: terminal (< d) or nonterminal (> d)
    rule_left: jnp.ndarray       # int32[max(R,1)]
    rule_right: jnp.ndarray      # int32[max(R,1)]
    # --- per-node expanded sizes ----------------------------------------
    doc_base: jnp.ndarray        # int32[L + I + 1] prefix sum of |D_v|
    # --- frequencies (top-k mode; empty in listing mode) ----------------
    freq_vals: jnp.ndarray       # int32[K]
    freq_gcum: jnp.ndarray       # int32[K] strictly-increasing global cum counts
    # --- static metadata --------------------------------------------------
    n: int
    d: int
    L: int
    I: int
    block_size: int
    beta: float | None
    nrules: int
    max_set_len: int
    max_rule_depth: int
    has_freqs: bool
    total_docs_stored: int

    def modeled_bits(self) -> int:
        """Paper Section 4.1 accounting: A, G, B_A, B_G, B_L, B_F, F, N
        (+ freq runs, delta-coded, for the top-k variant)."""
        L, I, n, d = self.L, self.I, self.n, self.d
        nR = self.nrules
        a_bits = int(self.A.shape[0]) * ceil_log2(d + nR + 1)
        g_bits = 2 * nR * ceil_log2(d + nR + 1)
        ba_bits = int(self.A.shape[0]) + 2 * (L + I)
        bl_bits = elias_fano_bits(L, max(n, 1))
        bf_bits = (L + I) + I * ceil_log2(max(2, I)) + I * ceil_log2(max(2, L))
        freq_bits = 0
        if self.has_freqs:
            fv = np.asarray(self.freq_vals)
            gc = np.asarray(self.freq_gcum)
            lens = np.diff(np.concatenate([[0], gc]))
            for v, ln in zip(fv.tolist(), lens.tolist()):
                freq_bits += delta_code_len(int(v) + 1) + delta_code_len(max(int(ln), 1))
        return a_bits + g_bits + ba_bits + bl_bits + bf_bits + freq_bits


# ===========================================================================
# Construction
# ===========================================================================


@dataclasses.dataclass
class _BuildState:
    leaf_bounds: list
    internal_children: list  # per internal node: child node ids
    internal_next_leaf: list


def _node_set(da: np.ndarray, lo: int, hi: int, topk: bool):
    seg = da[lo:hi]
    docs, counts = np.unique(seg, return_counts=True)
    if topk:
        order = np.lexsort((docs, -counts))
        return docs[order].astype(np.int64), counts[order].astype(np.int64)
    return docs.astype(np.int64), counts.astype(np.int64)


def build_pdl(
    data: SuffixData,
    block_size: int = 256,
    beta: float | None = 16.0,
    mode: str = "list",
    repair_kwargs: dict | None = None,
) -> PDLIndex:
    assert mode in ("list", "topk")
    topk = mode == "topk"
    da = np.asarray(data.da)
    n, d = data.n, data.d
    b = block_size

    tree = lcp_interval_tree(data.lcp)
    kids_of = tree.children_lists()
    sizes = tree.hi - tree.lo

    # root = the interval covering [0, n) (parent -1, max size); tiny
    # collections may lack internal nodes entirely.
    roots = [k for k in range(tree.size) if tree.parent[k] < 0]

    st = _BuildState([], [], [])

    leaf_ids: list[int] = []          # node ids of leaves, left-to-right
    node_is_leaf: list[bool] = []
    first_child_of: dict[int, int] = {}   # node id -> internal idx
    internal_ids: list[int] = []

    set_store: list[np.ndarray] = []
    freq_store: list[np.ndarray] = []

    def new_leaf(lo: int, hi: int) -> int:
        nid = len(set_store)
        docs, freqs = _node_set(da, lo, hi, topk)
        set_store.append(docs)
        freq_store.append(freqs)
        node_is_leaf.append(True)
        st.leaf_bounds.append((lo, hi))
        return nid

    # iterative post-order over big (> b) internal nodes
    # frame: [tree_node, unit list under construction, cursor pos, child idx]
    def process(root_k: int) -> list[int]:
        FRAME = object()
        stack = [[root_k, [], int(tree.lo[root_k]), 0, None]]
        result: dict[int, list[int]] = {}
        while stack:
            frame = stack[-1]
            k, units, cursor, ci, pending = frame
            children = [c for c in kids_of[k] if sizes[c] >= 2]
            # absorb a finished child cover
            if pending is not None:
                units.extend(result.pop(pending))
                frame[4] = None
            advanced = False
            while ci < len(children):
                c = children[ci]
                clo, chi = int(tree.lo[c]), int(tree.hi[c])
                # leading gap positions: single-suffix leaves
                while cursor < clo:
                    units.append(new_leaf(cursor, cursor + 1))
                    cursor += 1
                if chi - clo <= b:
                    units.append(new_leaf(clo, chi))
                    cursor = chi
                    ci += 1
                else:
                    # recurse
                    frame[1], frame[2], frame[3] = units, chi, ci + 1
                    frame[4] = c
                    stack.append([c, [], clo, 0, None])
                    advanced = True
                    break
                frame[1], frame[2], frame[3] = units, cursor, ci
            if advanced:
                continue
            # trailing gap positions
            hi_k = int(tree.hi[k])
            while cursor < hi_k:
                units.append(new_leaf(cursor, cursor + 1))
                cursor += 1
            # finalize node k
            stack.pop()
            docs, freqs = _node_set(da, int(tree.lo[k]), hi_k, topk)
            child_total = sum(len(set_store[u]) for u in units)
            keep = beta is None or child_total > beta * len(docs)
            if keep:
                nid = len(set_store)
                set_store.append(docs)
                freq_store.append(freqs)
                node_is_leaf.append(False)
                internal_ids.append(nid)
                st.internal_children.append(list(units))
                st.internal_next_leaf.append(len(st.leaf_bounds))
                cover = [nid]
            else:
                cover = list(units)
            if stack:
                result[k] = cover
            else:
                return cover
        return []

    top_cover: list[int] = []
    if tree.size == 0 or n <= b:
        # whole collection is one leaf block
        new_leaf(0, n)
        top_cover = [0]
    else:
        # find the root interval [0, n)
        root_k = max(roots, key=lambda k: int(sizes[k]))
        assert int(tree.lo[root_k]) == 0 and int(tree.hi[root_k]) == n
        top_cover = process(root_k)

    # ---- renumber: leaves first (creation order == left-to-right), then
    # internal nodes (creation order == post-order)
    old_ids = list(range(len(set_store)))
    leaf_old = [i for i in old_ids if node_is_leaf[i]]
    internal_old = [i for i in old_ids if not node_is_leaf[i]]
    remap = {}
    for new, old in enumerate(leaf_old):
        remap[old] = new
    L = len(leaf_old)
    for j, old in enumerate(internal_old):
        remap[old] = L + j
    I = len(internal_old)

    lists = [None] * (L + I)
    freqs_l = [None] * (L + I)
    for old, new in remap.items():
        lists[new] = set_store[old]
        freqs_l[new] = freq_store[old]

    leaf_bounds_sorted = sorted(st.leaf_bounds)
    leaf_starts = np.asarray(
        [lo for lo, _ in leaf_bounds_sorted] + [n], dtype=np.int32
    )
    # leaves must tile [0, n)
    ends = [hi for _, hi in leaf_bounds_sorted]
    assert leaf_starts[0] == 0 and ends[-1] == n
    assert all(ends[i] == leaf_starts[i + 1] for i in range(L))

    is_first_child = np.zeros(L + I, dtype=bool)
    parent_of = np.full(L + I, -1, dtype=np.int32)
    next_leaf = np.zeros(max(I, 1), dtype=np.int32)
    for j, _old in enumerate(internal_old):
        # creation order of internal nodes matches st.internal_children order
        children = st.internal_children[j]
        nl = st.internal_next_leaf[j]
        next_leaf[j] = nl
        first = remap[children[0]]
        is_first_child[first] = True
        parent_of[first] = j

    # ---- grammar compression of all lists (shared grammar)
    repair_kwargs = repair_kwargs or {}
    g, segments = repair_compress_lists(lists, alphabet=d, **repair_kwargs)
    assert len(segments) == L + I
    set_off = np.zeros(L + I + 1, dtype=np.int32)
    for i, seg in enumerate(segments):
        set_off[i + 1] = set_off[i] + len(seg)
    A = (
        np.concatenate(segments).astype(np.int32)
        if L + I
        else np.zeros(0, np.int32)
    )
    R = g.nrules
    rule_left = g.rules[:, 0].astype(np.int32) if R else np.zeros(1, np.int32)
    rule_right = g.rules[:, 1].astype(np.int32) if R else np.zeros(1, np.int32)

    # rule depth (for the query-time expansion stack bound)
    depth = np.zeros(max(R, 1), dtype=np.int64)
    for r in range(R):
        l, rr = g.rules[r]
        dl = 1 if l <= d else 1 + depth[l - d - 1]
        dr = 1 if rr <= d else 1 + depth[rr - d - 1]
        depth[r] = max(dl, dr)
    max_rule_depth = int(depth.max()) if R else 1

    # ---- per-node sizes and frequency runs
    set_sizes = np.asarray([len(x) for x in lists], dtype=np.int64)
    doc_base = np.concatenate([[0], np.cumsum(set_sizes)]).astype(np.int32)
    max_set_len = int(set_sizes.max()) if len(set_sizes) else 0

    freq_vals_l: list[int] = []
    gcum_l: list[int] = []
    running = 0
    if topk:
        for fl in freqs_l:
            fl = np.asarray(fl)
            if len(fl) == 0:
                continue
            change = np.flatnonzero(np.diff(fl)) + 1
            starts = np.concatenate([[0], change])
            ends_ = np.concatenate([change, [len(fl)]])
            for s, e in zip(starts, ends_):
                freq_vals_l.append(int(fl[s]))
                running += int(e - s)
                gcum_l.append(running)
    freq_vals = np.asarray(freq_vals_l if freq_vals_l else [0], dtype=np.int32)
    freq_gcum = np.asarray(gcum_l if gcum_l else [1], dtype=np.int32)

    return PDLIndex(
        leaf_starts=jnp.asarray(leaf_starts),
        is_first_child=jnp.asarray(is_first_child),
        parent_of=jnp.asarray(parent_of),
        next_leaf=jnp.asarray(next_leaf),
        set_off=jnp.asarray(set_off),
        A=jnp.asarray(A),
        rule_left=jnp.asarray(rule_left),
        rule_right=jnp.asarray(rule_right),
        doc_base=jnp.asarray(doc_base),
        freq_vals=jnp.asarray(freq_vals),
        freq_gcum=jnp.asarray(freq_gcum),
        n=n,
        d=d,
        L=L,
        I=I,
        block_size=block_size,
        beta=beta,
        nrules=R,
        max_set_len=max_set_len,
        max_rule_depth=max_rule_depth,
        has_freqs=topk,
        total_docs_stored=int(set_sizes.sum()),
    )


# ===========================================================================
# Query-time pieces (jit / vmap)
# ===========================================================================


def _expand_node_into(index: PDLIndex, nd, buf_docs, buf_freqs, base, cap):
    """Decompress node nd's list into buf starting at ``base``.

    Returns (buf_docs, buf_freqs, new_base).  Emits at most cap - base
    entries.  Frequencies come from the global run arrays (top-k mode);
    in listing mode buf_freqs is written with 1s.
    """
    d = index.d
    start = index.set_off[nd]
    end = index.set_off[nd + 1]
    stack_size = 2 * index.max_rule_depth + 4
    lenA = index.A.shape[0]
    iter_cap = 4 * index.max_set_len + 16

    def cond(c):
        ptr, sp, stack, bd, bf, cnt, it = c
        return ((ptr < end) | (sp > 0)) & (base + cnt < cap) & (it < iter_cap)

    def body(c):
        ptr, sp, stack, bd, bf, cnt, it = c
        from_stack = sp > 0
        sym = jnp.where(
            from_stack,
            stack[jnp.maximum(sp - 1, 0)],
            index.A[jnp.minimum(ptr, lenA - 1)],
        )
        sp = jnp.where(from_stack, sp - 1, sp)
        ptr = jnp.where(from_stack, ptr, ptr + 1)
        is_term = sym < d
        # emit terminal
        widx = jnp.where(is_term, base + cnt, cap)  # OOB -> dropped
        bd = bd.at[widx].set(sym, mode="drop")
        gpos = index.doc_base[nd] + cnt
        fidx = jnp.searchsorted(index.freq_gcum, gpos, side="right")
        fval = index.freq_vals[jnp.minimum(fidx, index.freq_vals.shape[0] - 1)]
        bf = bf.at[widx].set(
            jnp.where(index.has_freqs, fval, 1), mode="drop"
        )
        cnt = jnp.where(is_term, cnt + 1, cnt)
        # push rule children: right then left (left expands first)
        ridx = jnp.clip(sym - d - 1, 0, index.rule_left.shape[0] - 1)
        rl = index.rule_left[ridx]
        rr = index.rule_right[ridx]
        push = ~is_term
        s1 = jnp.minimum(sp, stack_size - 1)
        stack = jnp.where(push, stack.at[s1].set(rr), stack)
        sp = jnp.where(push, sp + 1, sp)
        s2 = jnp.minimum(sp, stack_size - 1)
        stack = jnp.where(push, stack.at[s2].set(rl), stack)
        sp = jnp.where(push, sp + 1, sp)
        return (ptr, sp, stack, bd, bf, cnt, it + 1)

    init = (
        start,
        as_i32(0),
        jnp.zeros(stack_size, IDX),
        buf_docs,
        buf_freqs,
        as_i32(0),
        as_i32(0),
    )
    ptr, sp, stack, bd, bf, cnt, it = jax.lax.while_loop(cond, body, init)
    return bd, bf, base + cnt


def _climb(index: PDLIndex, leaf_i, rn):
    """Fig 4 parent(): highest stored ancestor whose subtree fits in
    leaves [.., rn].  Returns (node id, next leaf index)."""
    L = index.L

    def cond(c):
        node, nxt, go = c
        return go

    def body(c):
        node, nxt, _ = c
        isf = index.is_first_child[node]
        par = index.parent_of[node]
        nl = index.next_leaf[jnp.clip(par, 0, max(index.I - 1, 0))]
        ok = isf & (par >= 0) & (nl - 1 <= rn)
        node2 = jnp.where(ok, L + par, node)
        nxt2 = jnp.where(ok, nl, nxt)
        return (node2, nxt2, ok)

    node, nxt, _ = jax.lax.while_loop(
        cond, body, (as_i32(leaf_i), as_i32(leaf_i) + 1, jnp.bool_(True))
    )
    return node, nxt


def _brute_window_into(csa: CSA, lo, hi, buf_docs, buf_freqs, base, cap, window: int):
    """CSA-locate a partial block [lo, hi) (hi - lo <= window) into buf
    with frequency-1 entries."""
    idx = as_i32(lo) + jnp.arange(window, dtype=IDX)
    valid = idx < hi
    pos = csa_lookup_batch(csa, jnp.minimum(idx, csa.n - 1))
    docs = jax.vmap(lambda p: csa.doc_bv.rank1(p + 1) - 1)(pos)
    offs = jnp.cumsum(valid.astype(IDX)) - 1
    widx = jnp.where(valid, base + offs, cap)
    buf_docs = buf_docs.at[widx].set(docs, mode="drop")
    buf_freqs = buf_freqs.at[widx].set(1, mode="drop")
    return buf_docs, buf_freqs, base + jnp.sum(valid.astype(IDX))


def _pdl_gather(index: PDLIndex, csa: CSA, lo, hi, max_buf: int, max_cover: int):
    """Shared query core: fill a buffer with (doc, tf) pairs covering
    SA[lo, hi) — partial blocks via CSA, full blocks via climb+expand.
    Returns (buf_docs, buf_freqs, count)."""
    lo = as_i32(lo)
    hi = as_i32(hi)
    L = index.L
    b = index.block_size
    leaf_starts = index.leaf_starts

    buf_docs = jnp.zeros(max_buf + 1, IDX)
    buf_freqs = jnp.zeros(max_buf + 1, IDX)
    cap = as_i32(max_buf)

    # full leaves: first leaf starting >= lo .. last leaf ending <= hi
    ln = jnp.searchsorted(leaf_starts[:L], lo, side="left").astype(IDX)
    n_full_ends = jnp.searchsorted(leaf_starts[1:], hi, side="right").astype(IDX)
    rn = n_full_ends - 1  # inclusive; may be < ln (no full leaves)

    # head partial: [lo, min(hi, leaf_starts[ln]))
    head_hi = jnp.minimum(hi, leaf_starts[jnp.minimum(ln, L)])
    base = as_i32(0)
    buf_docs, buf_freqs, base = _brute_window_into(
        csa, lo, head_hi, buf_docs, buf_freqs, base, cap, b
    )
    # tail partial: [leaf_starts[max(rn + 1, ln)], hi)
    tail_lo_idx = jnp.minimum(jnp.maximum(rn + 1, ln), L)
    tail_lo = jnp.maximum(leaf_starts[tail_lo_idx], head_hi)
    buf_docs, buf_freqs, base = _brute_window_into(
        csa, tail_lo, hi, buf_docs, buf_freqs, base, cap, b
    )

    # full blocks via climb + expansion
    def cond(c):
        i, bd, bf, base, it = c
        return (i <= rn) & (it < max_cover)

    def body(c):
        i, bd, bf, base, it = c
        node, nxt = _climb(index, i, rn)
        bd, bf, base = _expand_node_into(index, node, bd, bf, base, cap)
        return (nxt, bd, bf, base, it + 1)

    _, buf_docs, buf_freqs, base, _ = jax.lax.while_loop(
        cond, body, (ln, buf_docs, buf_freqs, base, as_i32(0))
    )
    return buf_docs[:max_buf], buf_freqs[:max_buf], base


def pdl_list_docs(
    index: PDLIndex, csa: CSA, lo, hi, max_df: int, max_buf: int = 4096,
    max_cover: int = 1024,
):
    """Document listing: distinct ids in DA[lo, hi).  Returns (docs, count)."""
    bd, bf, cnt = _pdl_gather(index, csa, lo, hi, max_buf, max_cover)
    valid = jnp.arange(max_buf, dtype=IDX) < cnt
    docs, count, _ = _distinct_from_window(bd, valid, max_df)
    return docs, count


def pdl_doc_freqs(
    index: PDLIndex, csa: CSA, lo, hi, max_buf: int = 4096, max_cover: int = 1024,
):
    """Aggregate (document, tf) pairs for SA[lo, hi).

    Returns (docs int32[max_buf] padded with INT32_MAX, tf int32[max_buf],
    ndocs).  This is the per-term primitive behind top-k and the TF-IDF
    index (Section 6.5): PDL lists merged brute-force by document.
    """
    bd, bf, cnt = _pdl_gather(index, csa, lo, hi, max_buf, max_cover)
    valid = jnp.arange(max_buf, dtype=IDX) < cnt
    big = jnp.iinfo(jnp.int32).max
    keys = jnp.where(valid, bd, big)
    order = jnp.argsort(keys)
    s_docs = keys[order]
    s_freqs = jnp.where(valid, bf, 0)[order]
    # segment-sum frequencies by document
    first = jnp.concatenate([jnp.ones(1, jnp.bool_), s_docs[1:] != s_docs[:-1]])
    is_doc = s_docs < big
    new_doc = first & is_doc
    cums = jnp.concatenate([jnp.zeros(1, IDX), jnp.cumsum(s_freqs)])
    pos = jnp.arange(max_buf, dtype=IDX)
    seg_id = jnp.cumsum(new_doc) - 1
    nseg = jnp.sum(new_doc).astype(IDX)
    total_valid = jnp.sum(is_doc).astype(IDX)
    seg_starts = jnp.zeros(max_buf + 1, IDX).at[
        jnp.where(new_doc, seg_id, max_buf + 1)
    ].set(pos, mode="drop")
    seg_starts = jnp.where(
        jnp.arange(max_buf + 1, dtype=IDX) < nseg, seg_starts, total_valid
    )
    # tf of segment s = cums[start of s+1] - cums[start of s]
    tf = cums[seg_starts[1:]] - cums[seg_starts[:-1]]
    seg_docs = s_docs[jnp.minimum(seg_starts[:max_buf], max_buf - 1)]
    seg_valid = jnp.arange(max_buf, dtype=IDX) < nseg
    seg_docs = jnp.where(seg_valid, seg_docs, big)
    tf = jnp.where(seg_valid, tf, 0)
    return seg_docs, tf, nseg


def pdl_list_docs_batch(
    index: PDLIndex, csa: CSA, lo, hi, max_df: int, max_buf: int = 4096,
    max_cover: int = 1024,
):
    """PDL listing over a range batch (masked-query contract of
    repro.core.listing): (docs int32[B, max_df] sorted asc, -1 padded,
    count[B])."""
    return jax.vmap(
        lambda a, b: pdl_list_docs(index, csa, a, b, max_df, max_buf, max_cover)
    )(as_i32(lo), as_i32(hi))


def pdl_doc_freqs_batch(
    index: PDLIndex, csa: CSA, lo, hi, max_buf: int = 4096, max_cover: int = 1024,
):
    """Batched per-term (doc, tf) aggregation: (docs[B, max_buf] padded
    INT32_MAX, tf[B, max_buf], ndocs[B])."""
    return jax.vmap(
        lambda a, b: pdl_doc_freqs(index, csa, a, b, max_buf, max_cover)
    )(as_i32(lo), as_i32(hi))


def pdl_topk_batch(
    index: PDLIndex, csa: CSA, lo, hi, k: int, max_buf: int = 4096,
    max_cover: int = 1024,
):
    """Batched top-k by (tf desc, id asc): (docs[B, k] padded -1, tf[B, k])."""
    return jax.vmap(
        lambda a, b: pdl_topk(index, csa, a, b, k, max_buf, max_cover)
    )(as_i32(lo), as_i32(hi))


def pdl_topk(
    index: PDLIndex, csa: CSA, lo, hi, k: int, max_buf: int = 4096,
    max_cover: int = 1024,
):
    """Top-k by term frequency (tf desc, id asc).  Returns (docs[k], tf[k])."""
    seg_docs, tf, nseg = pdl_doc_freqs(index, csa, lo, hi, max_buf, max_cover)
    big = jnp.iinfo(jnp.int32).max
    seg_valid = jnp.arange(max_buf, dtype=IDX) < nseg
    # rank by (tf desc, doc asc)
    negtf = jnp.where(seg_valid, -tf, big)
    dkey = jnp.where(seg_valid, seg_docs, big)
    order2 = jnp.lexsort((dkey, negtf))
    topd = dkey[order2[:k]]
    topf = -negtf[order2[:k]]
    ok = jnp.arange(k, dtype=IDX) < jnp.minimum(nseg, k)
    return (
        jnp.where(ok, topd, -1).astype(IDX),
        jnp.where(ok, topf, 0).astype(IDX),
    )
