"""Wavelet-tree document listing (Valimaki & Makinen 2007; the WT baseline
of Navarro et al. 2014, Section 6.2.1 of the paper).

The document array DA is stored in a wavelet matrix; the distinct documents
in DA[lo, hi) are enumerated by walking only the tree nodes whose interval
is non-empty — output-sensitive O(df lg d), and each reported document
arrives with its range frequency for free (hi' - lo' at the leaf), which is
why the paper's WT variant also answers top-k.

TPU form: explicit bounded stack in a ``lax.while_loop`` (same engineering
as the ILCP lister), vmap over query batches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32
from repro.succinct.wavelet import WaveletMatrix, wm_build


def build_da_wavelet(da, d: int) -> WaveletMatrix:
    return wm_build(da, d)


def wt_list_docs(wm: WaveletMatrix, lo, hi, max_df: int):
    """Distinct documents (+ frequencies) in DA[lo, hi).

    Returns (docs int32[max_df] padded -1, freqs int32[max_df], count).
    """
    lo = as_i32(lo)
    hi = as_i32(hi)
    L = wm.levels
    cap = max_df * (L + 1) + 4
    iter_cap = 4 * max_df * (L + 1) + 16

    # stack of (level, lo, hi, prefix)
    st = jnp.zeros((cap, 4), IDX).at[0].set(
        jnp.stack([as_i32(0), lo, hi, as_i32(0)])
    )
    init = (
        st,
        as_i32(1),
        jnp.full(max_df, -1, IDX),
        jnp.zeros(max_df, IDX),
        as_i32(0),
        as_i32(0),
    )

    def cond(state):
        _, sp, _, _, cnt, it = state
        return (sp > 0) & (cnt < max_df) & (it < iter_cap)

    def body(state):
        st, sp, docs, freqs, cnt, it = state
        lvl, a, b, val = st[sp - 1]
        sp = sp - 1
        is_leaf = lvl >= L
        nonempty = a < b

        # emit at leaves
        emit = is_leaf & nonempty & (cnt < max_df)
        widx = jnp.where(emit, cnt, max_df)
        docs = docs.at[widx].set(val, mode="drop")
        freqs = freqs.at[widx].set(b - a, mode="drop")
        cnt = jnp.where(emit, cnt + 1, cnt)

        # descend at internal nodes
        lvl_c = jnp.minimum(lvl, L - 1)
        z = wm.zcount[lvl_c]
        a0 = wm._rank0_level(lvl_c, a)
        b0 = wm._rank0_level(lvl_c, b)
        a1 = z + (a - a0)
        b1 = z + (b - b0)
        push = (~is_leaf) & nonempty

        def push_entry(st, sp, entry, do):
            idx = jnp.where(do & (sp < cap), sp, cap - 1)
            st = jnp.where(do & (sp < cap), st.at[idx].set(entry), st)
            return st, jnp.where(do & (sp < cap), sp + 1, sp)

        # push right first so the left child (smaller doc ids) pops first
        st, sp = push_entry(
            st, sp, jnp.stack([lvl + 1, a1, b1, (val << 1) | 1]),
            push & (a1 < b1),
        )
        st, sp = push_entry(
            st, sp, jnp.stack([lvl + 1, a0, b0, val << 1]), push & (a0 < b0)
        )
        return (st, sp, docs, freqs, cnt, it + 1)

    _, _, docs, freqs, cnt, _ = jax.lax.while_loop(cond, body, init)
    return docs, freqs, cnt


def wt_topk(wm: WaveletMatrix, lo, hi, k: int, max_df: int):
    """Top-k by frequency from the WT lister (tf desc, doc asc)."""
    docs, freqs, cnt = wt_list_docs(wm, lo, hi, max_df)
    from repro.core.listing import brute_topk

    return brute_topk(docs, cnt, freqs, k)


def wt_modeled_bits(wm: WaveletMatrix) -> int:
    """n lg d + o(n lg d) — the WT-over-DA baseline space."""
    from repro.succinct.wavelet import wm_modeled_bits

    return wm_modeled_bits(wm)
