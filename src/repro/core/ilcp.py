"""Interleaved LCP (ILCP) index — Section 3 of the paper.

Structure (Section 3.3): the ILCP array is stored *run-length encoded*:
  * ``L``     — sparse bitvector with a 1 at the start of each of the rho runs
  * ``vilcp`` — the run head values (stored once; also the RMQ's value array)
  * RMQ over VILCP (leftmost minimum — required by Lemma 3)
and for counting (Section 3.4):
  * a wavelet matrix over VILCP (the skewed wavelet tree's rank role;
    see repro.succinct.wavelet docstring for the equivalence note)
  * ``clens`` — cumulative lengths of the runs re-ordered by (value, pos):
    this is the paper's L' bitmap, stored as its select-prefix-sum, which
    weights run-head occurrences by their run lengths.

Query model (TPU adaptation): document listing is the Fig-1 recursion
realised as a bounded explicit stack inside ``lax.while_loop`` — each query
is O(df) iterations (every non-aborting pop reports >= 1 new document, every
aborting pop kills its whole subrange by Lemma 3).  A batch of queries is
``vmap`` over the same program.  Counting is the Fig-3 computation with the
value loop of the skewed tree replaced by a rank descent per value
(O(m lg lambda) instead of O(m); DESIGN.md Section 6).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, ceil_log2, elias_fano_bits, pytree_dataclass
from repro.core.csa import CSA, csa_da_at
from repro.core.suffix import SuffixData
from repro.succinct.bitvector import SparseBitvector, sparse_from_positions
from repro.succinct.rmq import SparseTableRMQ, rmq_build, rmq_query
from repro.succinct.wavelet import WaveletMatrix, wm_build, wm_rank_pair


@pytree_dataclass(meta=("n", "d", "nruns", "max_value"))
class ILCPIndex:
    L: SparseBitvector          # run starts (rho ones over n)
    rmq: SparseTableRMQ         # over VILCP (leftmost-min)
    wm: WaveletMatrix           # over VILCP values
    vilcp: jnp.ndarray          # int32[rho] run head values
    run_starts: jnp.ndarray     # int32[rho + 1] run boundaries (last = n)
    clens: jnp.ndarray          # int32[rho + 1] cum lengths, (value, pos) order
    value_run_offset: jnp.ndarray  # int32[max_value + 2] first sorted run per value
    n: int
    d: int
    nruns: int
    max_value: int

    # -- space accounting (Theorems 1 and 2) --------------------------------

    def modeled_bits_listing(self) -> int:
        """rho lg(n/rho) + O(rho) [L] + 2 rho [RMQ] + d lg(n/d) + O(d) [B]."""
        rho, n, d = self.nruns, self.n, self.d
        return (
            elias_fano_bits(rho, max(n, 1))
            + 2 * rho + max(1, rho // 4)
            + elias_fano_bits(d, max(n, 1))
        )

    def modeled_bits_counting(self) -> int:
        """rho(lg lambda + 2 lg(n/rho) + O(1)) — Theorem 2."""
        rho, n = self.nruns, self.n
        lam = max(2, self.max_value + 1)
        return rho * ceil_log2(lam) + 2 * elias_fano_bits(rho, max(n, 1)) + 2 * rho


def build_ilcp(data: SuffixData) -> ILCPIndex:
    ilcp = np.asarray(data.ilcp, dtype=np.int32)
    n = len(ilcp)
    d = data.d
    if n == 0:
        raise ValueError("empty collection")
    change = np.flatnonzero(np.diff(ilcp)) + 1
    run_starts = np.concatenate([[0], change]).astype(np.int32)
    rho = len(run_starts)
    vilcp = ilcp[run_starts]
    run_bounds = np.concatenate([run_starts, [n]]).astype(np.int32)
    lengths = np.diff(run_bounds)

    # value-sorted run lengths (the L' reordering of Section 3.4)
    order = np.lexsort((np.arange(rho), vilcp))
    clens = np.concatenate([[0], np.cumsum(lengths[order])]).astype(np.int32)
    sorted_vals = vilcp[order]
    max_value = int(vilcp.max()) if rho else 0
    value_run_offset = np.searchsorted(
        sorted_vals, np.arange(max_value + 2), side="left"
    ).astype(np.int32)

    return ILCPIndex(
        L=sparse_from_positions(run_starts, n),
        rmq=rmq_build(vilcp),
        wm=wm_build(vilcp, max_value + 1),
        vilcp=jnp.asarray(vilcp),
        run_starts=jnp.asarray(run_bounds),
        clens=jnp.asarray(clens),
        value_run_offset=jnp.asarray(value_run_offset),
        n=n,
        d=d,
        nruns=rho,
        max_value=max_value,
    )


def ilcp_num_runs(data: SuffixData) -> int:
    """rho, the quantity bounded by Lemma 2."""
    ilcp = np.asarray(data.ilcp)
    return int(1 + np.count_nonzero(np.diff(ilcp))) if len(ilcp) else 0


# ---------------------------------------------------------------------------
# Document listing (Fig 1) — bounded-stack while_loop, vmap-batchable
# ---------------------------------------------------------------------------


def _run_of(index: ILCPIndex, pos):
    return index.L.rank1(as_i32(pos) + 1) - 1


def ilcp_list_docs(index: ILCPIndex, get_da, lo, hi, max_df: int):
    """Distinct documents in DA[lo, hi) via the ILCP recursion.

    get_da: traced k -> document id (either a stored-DA gather, Sada-I-D,
    or a CSA locate + B-rank, Sada-I-L).
    Returns (docs int32[max_df] padded with -1, count).
    """
    lo = as_i32(lo)
    hi = as_i32(hi)
    d = index.d
    cap = max_df + 4
    iter_cap = 2 * max_df + 8

    lo_run = _run_of(index, lo)
    hi_run = _run_of(index, hi - 1)

    stack_a = jnp.zeros(cap, IDX).at[0].set(lo_run)
    stack_b = jnp.zeros(cap, IDX).at[0].set(hi_run)
    init = (
        stack_a,
        stack_b,
        as_i32(1),                       # stack pointer
        jnp.zeros(d, jnp.bool_),         # V
        jnp.full(max_df, -1, IDX),       # results
        as_i32(0),                       # count
        as_i32(0),                       # iterations (safety)
    )

    def cond(state):
        _, _, sp, _, _, cnt, it = state
        return (sp > 0) & (cnt < max_df) & (it < iter_cap)

    def body(state):
        sa_, sb_, sp, V, res, cnt, it = state
        a = sa_[sp - 1]
        b = sb_[sp - 1]
        sp = sp - 1
        valid = a <= b

        def process(V, res, cnt, sa_, sb_, sp):
            i_run = rmq_query(index.rmq, a, b)
            i = jnp.maximum(lo, index.run_starts[i_run])
            j = jnp.minimum(hi, index.run_starts[i_run + 1])

            def scan_cond(c):
                k, V, res, cnt, aborted = c
                return (k < j) & ~aborted & (cnt < max_df)

            def scan_body(c):
                k, V, res, cnt, aborted = c
                g = get_da(k)
                seen = V[g]
                V = V.at[g].set(True)
                res = jnp.where(
                    seen, res, res.at[jnp.minimum(cnt, max_df - 1)].set(g)
                )
                cnt = jnp.where(seen, cnt, cnt + 1)
                return (k + 1, V, res, cnt, seen)

            k, V, res, cnt, aborted = jax.lax.while_loop(
                scan_cond, scan_body, (i, V, res, cnt, jnp.bool_(False))
            )

            # push right subrange first, then left (left processed first —
            # required by Lemma 3 together with leftmost RMQ)
            def push(sa_, sb_, sp, x, y):
                do = (x <= y) & (sp < cap)
                sa_ = jnp.where(do, sa_.at[jnp.minimum(sp, cap - 1)].set(x), sa_)
                sb_ = jnp.where(do, sb_.at[jnp.minimum(sp, cap - 1)].set(y), sb_)
                return sa_, sb_, jnp.where(do, sp + 1, sp)

            def do_push(args):
                sa_, sb_, sp = args
                sa_, sb_, sp = push(sa_, sb_, sp, i_run + 1, b)
                sa_, sb_, sp = push(sa_, sb_, sp, a, i_run - 1)
                return sa_, sb_, sp

            sa_2, sb_2, sp2 = jax.lax.cond(
                aborted, lambda t: t, do_push, (sa_, sb_, sp)
            )
            return V, res, cnt, sa_2, sb_2, sp2

        def skip(V, res, cnt, sa_, sb_, sp):
            return V, res, cnt, sa_, sb_, sp

        V, res, cnt, sa_, sb_, sp = jax.lax.cond(
            valid & (lo < hi),
            lambda _: process(V, res, cnt, sa_, sb_, sp),
            lambda _: skip(V, res, cnt, sa_, sb_, sp),
            None,
        )
        return (sa_, sb_, sp, V, res, cnt, it + 1)

    _, _, _, _, res, cnt, _ = jax.lax.while_loop(cond, body, init)
    return res, cnt


def ilcp_list_docs_da(index: ILCPIndex, da: jnp.ndarray, lo, hi, max_df: int):
    """Sada-I-D: explicit document array (n lg d bits, fastest)."""
    return ilcp_list_docs(index, lambda k: da[k], lo, hi, max_df)


def ilcp_list_docs_csa(index: ILCPIndex, csa: CSA, lo, hi, max_df: int):
    """Sada-I-L: document ids via CSA locate + B-rank (Theorem 1 space)."""
    return ilcp_list_docs(index, lambda k: csa_da_at(csa, k), lo, hi, max_df)


def ilcp_list_docs_da_batch(index: ILCPIndex, da: jnp.ndarray, lo, hi, max_df: int,
                            *, use_rmq_kernel: bool = False):
    """Sada-I-D over a range batch (masked-query contract of
    repro.core.listing): returns (docs int32[B, max_df] padded -1, count[B]).
    Document ids are reported in *discovery* order — callers needing the
    canonical sorted layout sort rows (repro.serve.retrieval does).

    ``use_rmq_kernel=True`` swaps the vmap'd per-query recursion for the
    batch-lockstep oracle with the popped-interval RMQ routed through the
    batched Pallas RMQ kernel (``repro.kernels.ops.rmq``) — one launch per
    lockstep round instead of an XLA gather chain per query.  Answers are
    bit-identical either way; the default keeps the serve XLA path at zero
    ``pallas_call``s."""
    lo = as_i32(lo)
    hi = as_i32(hi)
    if not use_rmq_kernel:
        return jax.vmap(lambda a, b: ilcp_list_docs_da(index, da, a, b, max_df))(
            lo, hi
        )

    from repro.kernels import ops, ref

    def rmq_fn(a, b):
        return ops.rmq(index.vilcp, index.rmq.table, a, b)

    return ref.ilcp_list_ref(
        index.vilcp, index.rmq.table, index.run_starts, da, lo, hi,
        ops.runs_of(index.run_starts, lo),
        ops.runs_of(index.run_starts, hi - 1),
        d=index.d, max_df=max_df, rmq_fn=rmq_fn,
    )


def ilcp_list_docs_da_planned(index: ILCPIndex, da: jnp.ndarray, lo, hi,
                              max_df: int, *, use_kernel: bool | None = None,
                              block_q: int = 128, interpret: bool | None = None):
    """Sada-I-D listing written batch-first for the serving executor.

    Same integers as ``ilcp_list_docs_da_batch`` — documents in discovery
    order, bit-identical across paths.

    ``use_kernel`` selects the execution path:
      * ``None``  — auto: the fused Pallas kernel on TPU, XLA elsewhere;
      * ``True``  — force the fused kernel (``repro.kernels.ilcp_list``;
        one ``pallas_call`` for the whole batched recursion, interpret mode
        off-TPU unless ``interpret`` says otherwise);
      * ``False`` — force the XLA vmap'd while_loop path.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.ops import ilcp_list

        return ilcp_list(
            index.vilcp, index.rmq.table, index.run_starts, da, lo, hi,
            d=index.d, max_df=max_df, block_q=block_q, interpret=interpret,
        )
    return ilcp_list_docs_da_batch(index, da, lo, hi, max_df)


def ilcp_list_docs_csa_batch(index: ILCPIndex, csa: CSA, lo, hi, max_df: int):
    """Sada-I-L over a range batch; same contract as the -da variant."""
    return jax.vmap(lambda a, b: ilcp_list_docs_csa(index, csa, a, b, max_df))(
        as_i32(lo), as_i32(hi)
    )


# ---------------------------------------------------------------------------
# Document counting (Fig 3)
# ---------------------------------------------------------------------------


def ilcp_count_docs(index: ILCPIndex, lo, hi, m):
    """df = |{distinct docs in DA[lo, hi)}| = #{k in [lo, hi) : ILCP[k] < m}.

    m is the pattern length (Lemma 1).  Runs fully inside the range
    contribute via the L' cumulative lengths; the first/last run overlap is
    corrected exactly as in the paper's countDocuments.
    """
    lo = as_i32(lo)
    hi = as_i32(hi)
    m = as_i32(m)

    lo_run = _run_of(index, lo)
    hi_run = _run_of(index, jnp.maximum(hi - 1, lo))

    def per_value(v, acc):
        # both run boundaries share one wavelet descent (wm_rank_pair):
        # 2 rank gathers per level instead of the 4 of two wm_rank calls
        a, b = wm_rank_pair(index.wm, v, lo_run, hi_run + 1)
        off = index.value_run_offset[jnp.minimum(v, index.max_value + 1)]
        return acc + index.clens[off + b] - index.clens[off + a]

    vmax = jnp.minimum(m, index.max_value + 1)
    total = jax.lax.fori_loop(0, vmax, per_value, as_i32(0))

    # corrections: clip the first/last run to the query range
    v_lo = index.vilcp[lo_run]
    total = total - jnp.where(v_lo < m, lo - index.run_starts[lo_run], 0)
    v_hi = index.vilcp[hi_run]
    total = total - jnp.where(v_hi < m, index.run_starts[hi_run + 1] - hi, 0)

    return jnp.where(lo >= hi, 0, total).astype(IDX)


def ilcp_count_docs_batch(index: ILCPIndex, lo, hi, m):
    return jax.vmap(lambda a, b, c: ilcp_count_docs(index, a, b, c))(
        as_i32(lo), as_i32(hi), as_i32(m)
    )


# ---------------------------------------------------------------------------
# Host-side skewed wavelet tree (paper Fig 2) — reference + space model
# ---------------------------------------------------------------------------


class SkewedWaveletTree:
    """Literal host-side implementation of the Section 3.4 skewed shape:
    leaf for value i at depth 1 + 2*floor(lg(i+1)).  Used as the oracle for
    the jitted counting path and for modeled-space reporting.

    The tree is materialised as nested python nodes over numpy arrays; a
    node is (values_mask_bitvector, left, right).  Spine node S_k covers
    value groups k, k+1, ...; its left child is a balanced subtree over
    group k = values [2^{k-1}-1, 2^k-2].
    """

    def __init__(self, seq: np.ndarray, max_value: int):
        self.seq = np.asarray(seq, dtype=np.int64)
        self.max_value = max_value
        self.total_bits = 0
        self.root = self._build_spine(self.seq, 1)

    def _build_spine(self, seq, group):
        if len(seq) == 0:
            return None
        lo_v = (1 << (group - 1)) - 1
        hi_v = (1 << group) - 2  # inclusive
        if lo_v > self.max_value:
            return None
        go_left = seq <= hi_v
        self.total_bits += len(seq)
        left = self._build_balanced(seq[go_left], lo_v, min(hi_v, self.max_value))
        right = self._build_spine(seq[~go_left], group + 1)
        return ("spine", go_left, left, right)

    def _build_balanced(self, seq, lo_v, hi_v):
        if len(seq) == 0 or lo_v > hi_v:
            return None
        if lo_v == hi_v:
            return ("leaf", lo_v, len(seq))
        mid = (lo_v + hi_v) // 2
        go_left = seq <= mid
        self.total_bits += len(seq)
        return (
            "node",
            go_left,
            self._build_balanced(seq[go_left], lo_v, mid),
            self._build_balanced(seq[~go_left], mid + 1, hi_v),
        )

    def count_less(self, lo: int, hi: int, m: int) -> int:
        """Occurrences of values < m in seq[lo, hi) — O(m) nodes visited."""

        def walk(node, lo, hi):
            if node is None or lo >= hi:
                return 0
            kind = node[0]
            if kind == "leaf":
                _, value, _ = node
                return hi - lo if value < m else 0
            _, go_left, left, right = node
            pref = np.cumsum(go_left)
            nl_lo = int(pref[lo - 1]) if lo > 0 else 0
            nl_hi = int(pref[hi - 1]) if hi > 0 else 0
            total = 0
            # left subtree covers smaller values: descend if any value < m there
            total += walk(left, nl_lo, nl_hi)
            total += walk(right, lo - nl_lo, hi - nl_hi)
            return total

        return walk(self.root, lo, hi)

    def modeled_bits(self) -> int:
        return self.total_bits + max(1, self.total_bits // 8)
