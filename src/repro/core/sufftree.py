"""Suffix-tree topology from the LCP array (lcp-interval tree).

PDL (Section 4) and Sadakane's counting structure (Section 5) both need the
*shape* of the suffix tree, not its edges: every internal node corresponds
to an lcp-interval [lo, hi) of the suffix array (Abouelhoda et al. 2004).
This module enumerates those intervals and their nesting with the classic
stack sweep over LCP — O(n), host-side, build-time only.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LcpIntervalTree:
    """Internal suffix-tree nodes as lcp-intervals.

    depth[k], lo[k], hi[k]  — string depth and SA range [lo, hi) of node k.
    parent[k]               — index of the smallest enclosing interval (-1 root)
    Nodes are emitted in an order where children precede parents (post-order
    of the sweep); ``order_topdown`` gives parent-before-child order.
    Every node has hi - lo >= 2; single suffixes are implicit leaves.
    """

    depth: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    parent: np.ndarray

    @property
    def size(self) -> int:
        return len(self.depth)

    def order_topdown(self) -> np.ndarray:
        return np.lexsort((-(self.hi - self.lo), self.lo))

    def children_lists(self) -> list[list[int]]:
        kids: list[list[int]] = [[] for _ in range(self.size)]
        for k in range(self.size):
            p = self.parent[k]
            if p >= 0:
                kids[p].append(k)
        for lst in kids:
            lst.sort(key=lambda k: int(self.lo[k]))
        return kids


def lcp_interval_tree(lcp: np.ndarray) -> LcpIntervalTree:
    """Enumerate all lcp-intervals of an LCP array (root included)."""
    lcp = np.asarray(lcp, dtype=np.int64)
    n = len(lcp)
    depths: list[int] = []
    los: list[int] = []
    his: list[int] = []

    stack: list[list[int]] = [[0, 0]]  # (depth, lb)
    for i in range(1, n):
        l = int(lcp[i])
        lb = i - 1
        while stack and stack[-1][0] > l:
            d_, lb_ = stack.pop()
            depths.append(d_)
            los.append(lb_)
            his.append(i)
            lb = lb_
        if not stack or stack[-1][0] < l:
            stack.append([l, lb])
    while stack:
        d_, lb_ = stack.pop()
        depths.append(d_)
        los.append(lb_)
        his.append(n)

    depth = np.asarray(depths, dtype=np.int64)
    lo = np.asarray(los, dtype=np.int64)
    hi = np.asarray(his, dtype=np.int64)

    # dedupe + drop degenerate size-1 intervals
    key = lo * (n + 1) + hi
    _, first = np.unique(key, return_index=True)
    keep = np.sort(first)
    depth, lo, hi = depth[keep], lo[keep], hi[keep]
    ok = (hi - lo) >= 2
    depth, lo, hi = depth[ok], lo[ok], hi[ok]

    # parents by nesting: top-down sweep with a stack
    order = np.lexsort((-(hi - lo), lo))
    parent = np.full(len(lo), -1, dtype=np.int64)
    st: list[int] = []
    for k in order:
        while st and not (lo[st[-1]] <= lo[k] and hi[k] <= hi[st[-1]]):
            st.pop()
        if st:
            # guard against duplicate-range nodes (shouldn't happen post-dedupe)
            parent[k] = st[-1]
        st.append(int(k))
    return LcpIntervalTree(depth=depth, lo=lo, hi=hi, parent=parent)
