"""Baseline document listing / top-k algorithms (Section 6.2.1 / 6.3.1).

* Brute-D — sort the stored DA[lo, hi) slice, report distinct ids (+ freqs).
* Brute-L — same, but document ids come from CSA locate + B-rank.
* Sada-C  — Sadakane's RMQ recursion over Muthukrishnan's C array with the
            V-marking optimization (the paper's Sada-C-L / Sada-C-D).

These are the paper's own baselines and also the engines behind the top-k
brute variants and the PDL fallback for short ranges.

TPU adaptation: Brute-X sorts a fixed-width window (max_occ) — a dense
``jnp.sort`` is exactly what the VPU is good at, making Brute the *strong*
baseline on accelerators, as the paper observes it is on CPUs for small
occ/df.  All functions are vmap-ready over (lo, hi).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32
from repro.core.csa import CSA, csa_da_at, csa_lookup_batch
from repro.succinct.rmq import SparseTableRMQ, rmq_query


# ---------------------------------------------------------------------------
# Brute force
# ---------------------------------------------------------------------------


def _distinct_from_window(window, valid, max_df: int):
    """Given a gathered doc-id window (int32[max_occ]) and validity mask,
    return (docs[max_df] padded -1, count, freqs[max_df])."""
    big = jnp.iinfo(jnp.int32).max
    keys = jnp.where(valid, window, big)
    s = jnp.sort(keys)
    first = jnp.concatenate([jnp.ones(1, jnp.bool_), s[1:] != s[:-1]])
    is_doc = s < big
    new_doc = first & is_doc
    # distinct ids in sorted order, compacted to the front; non-writes are
    # routed to an out-of-bounds index and dropped.
    idx_among_new = jnp.cumsum(new_doc) - 1
    scatter_idx = jnp.where(new_doc, idx_among_new, max_df).astype(IDX)
    docs = jnp.full(max_df, -1, IDX)
    docs = docs.at[scatter_idx].set(s.astype(IDX), mode="drop")
    count = jnp.minimum(jnp.sum(new_doc), max_df).astype(IDX)
    # frequencies: segment boundaries in the sorted window
    pos = jnp.arange(s.shape[0], dtype=IDX)
    starts = jnp.full(max_df + 1, jnp.sum(is_doc), IDX)
    starts_idx = jnp.where(new_doc, idx_among_new, max_df + 1).astype(IDX)
    starts = starts.at[starts_idx].set(pos, mode="drop")
    freqs = jnp.where(
        jnp.arange(max_df) < count, starts[1:] - starts[:-1], 0
    ).astype(IDX)
    docs = jnp.where(jnp.arange(max_df, dtype=IDX) < count, docs, -1)
    return docs, count, freqs


def brute_list_da(da: jnp.ndarray, lo, hi, max_occ: int, max_df: int | None = None):
    """Brute-D: distinct docs (+freqs) in DA[lo, hi), window cap max_occ.

    Returns (docs[max_df], count, freqs[max_df]).  Ranges longer than
    max_occ are truncated (callers size max_occ from query statistics, as
    the paper sizes its experiments by occ).
    """
    max_df = max_df or max_occ
    lo = as_i32(lo)
    hi = as_i32(hi)
    idx = lo + jnp.arange(max_occ, dtype=IDX)
    valid = idx < hi
    window = da[jnp.minimum(idx, da.shape[0] - 1)]
    return _distinct_from_window(window, valid, max_df)


def brute_list_csa(csa: CSA, lo, hi, max_occ: int, max_df: int | None = None):
    """Brute-L: ids via locate (the paper's least-space baseline)."""
    max_df = max_df or max_occ
    lo = as_i32(lo)
    hi = as_i32(hi)
    idx = lo + jnp.arange(max_occ, dtype=IDX)
    valid = idx < hi
    text_pos = csa_lookup_batch(csa, jnp.minimum(idx, csa.n - 1))
    window = jax.vmap(lambda p: csa.doc_bv.rank1(p + 1) - 1)(text_pos)
    return _distinct_from_window(window, valid, max_df)


def brute_topk(docs, count, freqs, k: int):
    """Top-k by tf desc, ties by doc id asc (paper Section 4.2 ordering).

    Input from brute_list_*; returns (top_docs[k], top_freqs[k]).
    """
    max_df = docs.shape[0]
    valid = jnp.arange(max_df, dtype=IDX) < count
    # sort by (-freq, doc); invalid entries sort last
    big = jnp.iinfo(jnp.int32).max
    negfreq = jnp.where(valid, -freqs, big)
    doc_key = jnp.where(valid, docs, big)
    order = jnp.lexsort((doc_key, negfreq))
    kk = min(k, max_df)
    top = order[:kk]
    out_docs = jnp.full(k, -1, IDX).at[:kk].set(docs[top])
    out_freqs = jnp.zeros(k, IDX).at[:kk].set(freqs[top])
    ok = jnp.arange(k, dtype=IDX) < jnp.minimum(count, k)
    return (
        jnp.where(ok, out_docs, -1).astype(IDX),
        jnp.where(ok, out_freqs, 0).astype(IDX),
    )


# ---------------------------------------------------------------------------
# Fixed-shape batch entry points (vmapped, mask-friendly)
# ---------------------------------------------------------------------------
#
# Contract shared by every *_batch executor in repro.core: inputs are dense
# int32[B] range arrays where a *masked-out* query is the empty range
# (lo, hi) = (0, 0); outputs are padded (B, max_df) doc arrays with -1
# sentinels past the per-query count.  Empty ranges cost one bounded loop
# iteration and report count 0, so a planner (repro.serve.planner) can hand
# each engine the full batch with only its sub-batch live.


def brute_list_da_batch(da: jnp.ndarray, lo, hi, max_occ: int, max_df: int):
    """Brute-D over a range batch: (docs[B, max_df], count[B], freqs)."""
    return jax.vmap(lambda a, b: brute_list_da(da, a, b, max_occ, max_df))(
        as_i32(lo), as_i32(hi)
    )


def brute_list_csa_batch(csa: CSA, lo, hi, max_occ: int, max_df: int):
    """Brute-L over a range batch: (docs[B, max_df], count[B], freqs)."""
    return jax.vmap(lambda a, b: brute_list_csa(csa, a, b, max_occ, max_df))(
        as_i32(lo), as_i32(hi)
    )


def brute_topk_batch(docs, counts, freqs, k: int):
    """Row-wise top-k of brute_list_*_batch output: (docs[B, k], tf[B, k])."""
    return jax.vmap(lambda d, c, f: brute_topk(d, c, f, k))(docs, counts, freqs)


# ---------------------------------------------------------------------------
# Sadakane's algorithm over the C array (Sada-C)
# ---------------------------------------------------------------------------


def sada_c_list_docs(
    rmq_c: SparseTableRMQ, get_da, lo, hi, d: int, max_df: int
):
    """Sadakane (2007): RMQ recursion over C with V-marking.

    Identical control structure to the ILCP lister but per *position*:
    pop range, take leftmost min k, if DA[k] unseen report + split,
    else prune the whole range (C[k] >= lo check is replaced by V, which is
    the paper's own space optimization).
    """
    lo = as_i32(lo)
    hi = as_i32(hi)
    cap = max_df + 4
    iter_cap = 2 * max_df + 8

    stack_a = jnp.zeros(cap, IDX).at[0].set(lo)
    stack_b = jnp.zeros(cap, IDX).at[0].set(hi - 1)
    init = (
        stack_a,
        stack_b,
        as_i32(1),
        jnp.zeros(d, jnp.bool_),
        jnp.full(max_df, -1, IDX),
        as_i32(0),
        as_i32(0),
    )

    def cond(state):
        _, _, sp, _, _, cnt, it = state
        return (sp > 0) & (cnt < max_df) & (it < iter_cap)

    def body(state):
        sa_, sb_, sp, V, res, cnt, it = state
        a = sa_[sp - 1]
        b = sb_[sp - 1]
        sp = sp - 1
        valid = (a <= b) & (lo < hi)

        k = rmq_query(rmq_c, jnp.minimum(a, hi - 1), jnp.minimum(b, hi - 1))
        g = get_da(k)
        seen = V[g] | ~valid

        V = jnp.where(valid & ~seen, V.at[g].set(True), V)
        res = jnp.where(
            valid & ~seen, res.at[jnp.minimum(cnt, max_df - 1)].set(g), res
        )
        cnt = jnp.where(valid & ~seen, cnt + 1, cnt)

        def push(sa_, sb_, sp, x, y, do):
            do = do & (x <= y) & (sp < cap)
            sa_ = jnp.where(do, sa_.at[jnp.minimum(sp, cap - 1)].set(x), sa_)
            sb_ = jnp.where(do, sb_.at[jnp.minimum(sp, cap - 1)].set(y), sb_)
            return sa_, sb_, jnp.where(do, sp + 1, sp)

        grow = valid & ~seen
        sa_, sb_, sp = push(sa_, sb_, sp, k + 1, b, grow)
        sa_, sb_, sp = push(sa_, sb_, sp, a, k - 1, grow)
        return (sa_, sb_, sp, V, res, cnt, it + 1)

    _, _, _, _, res, cnt, _ = jax.lax.while_loop(cond, body, init)
    return res, cnt


def sada_c_list_docs_da(rmq_c, da: jnp.ndarray, lo, hi, d: int, max_df: int):
    return sada_c_list_docs(rmq_c, lambda k: da[k], lo, hi, d, max_df)


def sada_c_list_docs_csa(rmq_c, csa: CSA, lo, hi, max_df: int):
    return sada_c_list_docs(
        rmq_c, lambda k: csa_da_at(csa, k), lo, hi, csa.d, max_df
    )
