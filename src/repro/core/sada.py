"""Sadakane's document-counting structure, engineered for repetitiveness
(Section 5).

The structure: for the binary suffix tree, H[i] = h(v) (redundant suffixes)
listed in inorder; encoded in unary as bitvector H' (one '1' per slot, then
H[i] '0's).  Given the locus range [lo, hi) of P,

    df = (hi - lo) - sum_{slots k in (lo, hi)} H[k]

and the sum is two select_1 operations on H' (Section 5.1).

Construction here avoids explicit binarization by combining the paper's
reordering trick (Section 5.2 item 1 — only per-original-node sums matter)
with a pair-charging argument: every *adjacent same-document pair*
(i, nextocc(i)) is one redundant suffix, resolved exactly at the LCA of the
two SA positions.  Charging the pair to the slot at the leftmost minimum of
LCP[i+1..j] places it inside that LCA's slot range, so every node-aligned
subtree sum is exact — a fully vectorized O(n lg n) build.

Encodings (Section 6.4.1): the same H values can be wrapped as
  * Sada      — plain bitvector H'
  * Sada-RR   — run-length encoded H' (delta-coded model)
  * Sada-S    — sparse (Elias-Fano) H'
  * Sada-S-S  — sparse H' restricted to H > 1 slots + sparse 1-filter F_1
  * Sada-F-P  — sparse filter F_S (H > 0) + plain H' over nonzero slots
All variants answer the same query through rank/select; they differ in the
working bitvector family and the modeled compressed size.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, pytree_dataclass
from repro.core.suffix import SuffixData
from repro.succinct.bitvector import (
    PlainBitvector,
    RLEBitvector,
    SparseBitvector,
    plain_from_bits,
    rle_from_bits,
    sparse_from_bits,
)

VARIANTS = ("plain", "rle", "sparse", "sparse_sparse", "filter_plain")


# ---------------------------------------------------------------------------
# Build: H slot values
# ---------------------------------------------------------------------------


def _argmin_table(values: np.ndarray):
    """numpy sparse table of leftmost argmins (build-time batched RMQ)."""
    n = len(values)
    levels = max(1, int(np.floor(np.log2(max(n, 1)))) + 1)
    table = [np.arange(n, dtype=np.int64)]
    for k in range(1, levels):
        half = 1 << (k - 1)
        prev = table[-1]
        right_idx = np.minimum(np.arange(n) + half, n - 1)
        right = prev[right_idx]
        left = prev
        take_right = values[right] < values[left]
        table.append(np.where(take_right, right, left))
    return table


def _batch_leftmost_argmin(values, table, lo, hi):
    """Leftmost argmin of values[lo..hi] inclusive, vectorized over arrays."""
    lo = np.asarray(lo, dtype=np.int64)
    hi = np.asarray(hi, dtype=np.int64)
    span = np.maximum(hi - lo + 1, 1)
    k = np.floor(np.log2(span)).astype(np.int64)
    kmax = len(table) - 1
    k = np.minimum(k, kmax)
    a = np.empty(len(lo), dtype=np.int64)
    b = np.empty(len(lo), dtype=np.int64)
    for kk in np.unique(k):
        m = k == kk
        a[m] = table[kk][lo[m]]
        b[m] = table[kk][np.maximum(hi[m] - (1 << int(kk)) + 1, lo[m])]
    va = values[a]
    vb = values[b]
    pick_b = (vb < va) | ((vb == va) & (b < a))
    return np.where(pick_b, b, a)


def compute_h_slots(data: SuffixData) -> np.ndarray:
    """H[k] for slots k in [1, n): redundant-suffix counts charged to the
    leftmost-minimum LCP slot of each adjacent same-document pair."""
    n = data.n
    H = np.zeros(n, dtype=np.int64)
    c = np.asarray(data.c)
    # next-occurrence pairs: (c[i], i) for c[i] >= 0
    j = np.flatnonzero(c >= 0)
    i = c[j].astype(np.int64)
    if len(j) == 0:
        return H
    lcp = np.asarray(data.lcp, dtype=np.int64)
    table = _argmin_table(lcp)
    k = _batch_leftmost_argmin(lcp, table, i + 1, j)
    np.add.at(H, k, 1)
    H[0] = 0
    return H


# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------


def _unary_bits(values: np.ndarray) -> np.ndarray:
    """'1' + v '0's per value."""
    total = len(values) + int(values.sum())
    bits = np.zeros(total, dtype=np.uint8)
    pos = np.cumsum(np.concatenate([[0], values[:-1] + 1])) if len(values) else np.zeros(0, np.int64)
    bits[pos.astype(np.int64)] = 1
    return bits


@pytree_dataclass(meta=("n", "variant", "num_slots"))
class SadaCount:
    """One of the Section 6.4.1 encodings of Sadakane's structure.

    hp:  unary H' bitvector (full, or restricted per the variant)
    fs:  sparse filter over slots (meaning depends on variant; dummy when
         unused — the static ``variant`` decides the code path)
    f1:  sparse 1-filter (slots with H == 1)
    """

    hp: PlainBitvector | RLEBitvector | SparseBitvector
    fs: SparseBitvector
    f1: SparseBitvector
    n: int
    variant: str
    num_slots: int

    def modeled_bits(self) -> int:
        bits = self.hp.modeled_bits()
        if self.variant in ("sparse_sparse", "filter_plain"):
            bits += self.fs.modeled_bits()
        if self.variant == "sparse_sparse":
            bits += self.f1.modeled_bits()
        return bits


def _dummy_sparse(n: int) -> SparseBitvector:
    return sparse_from_bits(np.zeros(max(n, 1), dtype=np.uint8))


def build_sada(data: SuffixData, variant: str = "plain") -> SadaCount:
    assert variant in VARIANTS
    n = data.n
    H = compute_h_slots(data)  # H[0] unused; slots 1..n-1
    slots = H[1:]
    num_slots = len(slots)

    fs = _dummy_sparse(n)
    f1 = _dummy_sparse(n)

    if variant in ("plain", "rle", "sparse"):
        bits = _unary_bits(slots)
        if variant == "plain":
            hp = plain_from_bits(bits)
        elif variant == "rle":
            hp = rle_from_bits(bits)
        else:
            hp = sparse_from_bits(bits)
    elif variant == "filter_plain":
        # F_S marks slots with H > 0 (offset by +1 into slot space)
        mask = slots > 0
        fs_bits = np.zeros(n, dtype=np.uint8)
        fs_bits[1:][mask] = 1
        fs = sparse_from_bits(fs_bits)
        hp = plain_from_bits(_unary_bits(slots[mask]))
    else:  # sparse_sparse: F_S marks H > 1, F_1 marks H == 1
        mask_gt1 = slots > 1
        mask_eq1 = slots == 1
        fs_bits = np.zeros(n, dtype=np.uint8)
        fs_bits[1:][mask_gt1] = 1
        f1_bits = np.zeros(n, dtype=np.uint8)
        f1_bits[1:][mask_eq1] = 1
        fs = sparse_from_bits(fs_bits)
        f1 = sparse_from_bits(f1_bits)
        hp = sparse_from_bits(_unary_bits(slots[mask_gt1]))

    return SadaCount(hp=hp, fs=fs, f1=f1, n=n, variant=variant, num_slots=num_slots)


# ---------------------------------------------------------------------------
# Query
# ---------------------------------------------------------------------------


def _unary_prefix_sum(hp, t):
    """sum of the first t unary-coded values = select1(t) - t  (select1 of an
    out-of-range t returns the bitvector length, which keeps the identity)."""
    return hp.select1(t) - t


def sada_count(s: SadaCount, lo, hi):
    """df for the locus range [lo, hi) — exact for suffix-tree-node-aligned
    ranges (the structure's contract, as in the paper)."""
    lo = as_i32(lo)
    hi = as_i32(hi)
    a = lo + 1  # slot ids are LCP positions; slots in (lo, hi)
    b = hi

    if s.variant in ("plain", "rle", "sparse"):
        # stored slot t <-> slot id t + 1
        a_ = a - 1
        b_ = b - 1
        dup = _unary_prefix_sum(s.hp, b_) - _unary_prefix_sum(s.hp, a_)
    elif s.variant == "filter_plain":
        a_ = s.fs.rank1(a)
        b_ = s.fs.rank1(b)
        dup = _unary_prefix_sum(s.hp, b_) - _unary_prefix_sum(s.hp, a_)
    else:  # sparse_sparse
        ones = s.f1.rank1(b) - s.f1.rank1(a)
        a_ = s.fs.rank1(a)
        b_ = s.fs.rank1(b)
        dup = ones + _unary_prefix_sum(s.hp, b_) - _unary_prefix_sum(s.hp, a_)

    df = (hi - lo) - dup
    return jnp.where(hi > lo, df, 0).astype(IDX)


def sada_count_batch(s: SadaCount, lo, hi):
    return jax.vmap(lambda a, b: sada_count(s, a, b))(as_i32(lo), as_i32(hi))


# ---------------------------------------------------------------------------
# Analysis helper (Fig 5): runs of 1s in H'
# ---------------------------------------------------------------------------


def hprime_runs_of_ones(data: SuffixData) -> int:
    H = compute_h_slots(data)[1:]
    bits = _unary_bits(H)
    if len(bits) == 0:
        return 0
    starts = (bits[1:] == 1) & (bits[:-1] == 0)
    return int(starts.sum()) + int(bits[0] == 1)
