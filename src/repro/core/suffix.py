"""Suffix-array machinery: SA, LCP, document array, C array, ILCP inputs.

Construction strategy (TPU-native, DESIGN.md Section 2.3):

* The suffix array is built by **prefix doubling** — O(lg n) rounds of
  ``lexsort`` — because sorting is the parallel primitive accelerators are
  good at (SA-IS-style induced copying is inherently sequential pointer
  chasing).  Each round is pure vectorized dataflow.

* The per-round rank tables are retained; any pairwise LCP between two text
  positions is then an O(lg n) *vectorized descent* over the tables.  This
  one primitive produces: the global LCP array (adjacent SA entries), the
  classic C array of Muthukrishnan (previous same-document occurrence), and
  the ILCP array of the paper (Definition 1) — because Lemma 1's
  order-preservation argument makes ILCP[i] the within-document LCP of
  SA[i] against the *previous same-document* suffix in SA order, and
  per-document sentinels make within-document LCP equal global char-LCP.

Sentinel semantics (paper-faithful): documents are concatenated with a
shared terminator symbol 0 ("$") after each, lexicographically smaller than
every regular symbol, and suffix comparison continues *past* terminators —
i.e. SA is the plain suffix array of the concatenation T.  The paper's
running example fixes this choice (its SA orders "$" < "$AAAA$" <
"$LATA$...").  Two suffixes of the *same* document can never tie through
that document's terminator, so Lemma 1's order-preservation argument holds,
and within-document LCPs equal global char-LCPs.  A bonus of the
single-string view: the FM-index LF identity is exact with no multi-$
caveats.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX


# ---------------------------------------------------------------------------
# Collection assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Collection:
    """A concatenated document collection T = S_0 $ S_1 $ ... $ S_{d-1} $.

    text:       int32[n]   symbols; 0 is the per-document terminator
    doc_starts: int32[d]   start offset of each document
    doc_ends:   int32[d]   offset of each document's terminator
    d:          number of documents
    sigma:      alphabet size including the terminator (max symbol + 1)
    """

    text: np.ndarray
    doc_starts: np.ndarray
    doc_ends: np.ndarray
    d: int
    sigma: int

    @property
    def n(self) -> int:
        return int(self.text.shape[0])

    def doc_of(self, pos: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.doc_starts, pos, side="right") - 1


def concat_documents(docs: Sequence) -> Collection:
    """Concatenate documents (strings or int arrays) with terminators.

    String documents are mapped byte-wise to [1, 256]; integer documents
    must be >= 0 and are shifted by +1 so that 0 is free for the terminator.
    """
    arrays = []
    for doc in docs:
        if isinstance(doc, str):
            a = np.frombuffer(doc.encode("utf-8"), dtype=np.uint8).astype(np.int32) + 1
        else:
            a = np.asarray(doc, dtype=np.int32) + 1
            if a.size and a.min() < 1:
                raise ValueError("integer documents must have symbols >= 0")
        arrays.append(a)
    starts, ends, parts = [], [], []
    off = 0
    for a in arrays:
        starts.append(off)
        parts.append(a)
        off += len(a)
        ends.append(off)
        parts.append(np.zeros(1, dtype=np.int32))
        off += 1
    text = np.concatenate(parts) if parts else np.zeros(0, dtype=np.int32)
    sigma = int(text.max()) + 1 if text.size else 1
    return Collection(
        text=text,
        doc_starts=np.asarray(starts, dtype=np.int32),
        doc_ends=np.asarray(ends, dtype=np.int32),
        d=len(arrays),
        sigma=sigma,
    )


def subcollection(coll: Collection, dlo: int, dhi: int) -> Collection:
    """The contiguous document slice ``[dlo, dhi)`` of ``coll`` as its own
    Collection — the unit a docs-axis shard indexes.

    The slice keeps the parent's **global sigma**, so every shard's wavelet
    matrix descends the same symbol levels and a pattern encodes identically
    against every shard.  Because each document ends in its own terminator
    and patterns never contain the terminator, a pattern's occurrences
    inside documents ``[dlo, dhi)`` are exactly its occurrences inside the
    slice: per-shard occ / df / document sets sum (resp. disjoint-union) to
    the global answer.
    """
    if not (0 <= dlo <= dhi <= coll.d):
        raise ValueError(f"document slice [{dlo}, {dhi}) out of range for d={coll.d}")
    if dlo == dhi:
        return Collection(
            text=np.zeros(0, dtype=np.int32),
            doc_starts=np.zeros(0, dtype=np.int32),
            doc_ends=np.zeros(0, dtype=np.int32),
            d=0,
            sigma=coll.sigma,
        )
    base = int(coll.doc_starts[dlo])
    stop = int(coll.doc_ends[dhi - 1]) + 1  # include the last terminator
    return Collection(
        text=np.ascontiguousarray(coll.text[base:stop]),
        doc_starts=(coll.doc_starts[dlo:dhi] - base).astype(np.int32),
        doc_ends=(coll.doc_ends[dlo:dhi] - base).astype(np.int32),
        d=dhi - dlo,
        sigma=coll.sigma,
    )


# ---------------------------------------------------------------------------
# Prefix-doubling suffix array (device) + retained rank tables
# ---------------------------------------------------------------------------


def _initial_ranks(coll: Collection) -> np.ndarray:
    """Initial single-symbol ranks.  The terminator (symbol 0) is shared by
    all documents and smaller than every regular symbol — plain suffix-array
    semantics of the concatenation, as in the paper's running example."""
    text = coll.text
    order = np.argsort(text, kind="stable")
    sorted_keys = text[order]
    new_group = np.empty(len(text), dtype=np.int64)
    if len(text):
        new_group[0] = 0
        new_group[1:] = (sorted_keys[1:] != sorted_keys[:-1]).astype(np.int64)
    dense = np.cumsum(new_group) if len(text) else new_group
    rank = np.empty(len(text), dtype=np.int64)
    rank[order] = dense
    return rank.astype(np.int32)


def suffix_array_doubling(coll: Collection, keep_tables: bool = True):
    """Return (sa, rank_tables) where rank_tables[j] ranks length-2^j
    substrings (rank_tables[0] = single-symbol ranks with distinct
    sentinels).  All rounds run as device-parallel sorts.
    """
    n = coll.n
    if n == 0:
        return np.zeros(0, np.int32), [np.zeros(0, np.int32)]
    rank = jnp.asarray(_initial_ranks(coll))
    tables = [np.asarray(rank)] if keep_tables else []
    idx = jnp.arange(n, dtype=IDX)
    k = 1
    sa = jnp.argsort(rank)  # valid if ranks already unique
    while True:
        if int(jax.device_get(rank.max())) == n - 1:
            sa = jnp.argsort(rank)
            break
        key2 = jnp.where(idx + k < n, rank[jnp.minimum(idx + k, n - 1)], -1)
        order = jnp.lexsort((key2, rank))
        r_s = rank[order]
        k_s = key2[order]
        boundary = jnp.concatenate(
            [
                jnp.zeros(1, IDX),
                ((r_s[1:] != r_s[:-1]) | (k_s[1:] != k_s[:-1])).astype(IDX),
            ]
        )
        dense = jnp.cumsum(boundary)
        rank = jnp.zeros(n, IDX).at[order].set(dense)
        if keep_tables:
            tables.append(np.asarray(rank))
        sa = order
        k *= 2
        if k >= 2 * n:  # all suffixes must be distinct by now
            break
    return np.asarray(sa, dtype=np.int32), tables


def pairwise_lcp(tables: list, a: np.ndarray, b: np.ndarray, n: int) -> np.ndarray:
    """Vectorized char-LCP of suffixes starting at positions a and b.

    Descends the doubling rank tables from the widest span: if the ranks of
    length-2^j windows match, those 2^j symbols are equal (terminators are
    ordinary symbols under the shared-$ semantics, matching the paper's
    plain char-LCP over T).
    """
    a = np.asarray(a, dtype=np.int64).copy()
    b = np.asarray(b, dtype=np.int64).copy()
    res = np.zeros(a.shape, dtype=np.int64)
    for j in range(len(tables) - 1, -1, -1):
        span = 1 << j
        ai = a + res
        bi = b + res
        ok = (ai < n) & (bi < n)
        ai_c = np.minimum(ai, n - 1)
        bi_c = np.minimum(bi, n - 1)
        t = tables[j]
        ok &= t[ai_c] == t[bi_c]
        res = np.where(ok, res + span, res)
    return res.astype(np.int32)


# ---------------------------------------------------------------------------
# Full build product
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SuffixData:
    """Host-side build artifact shared by every index in repro.core.

    sa:    int32[n]  suffix array
    rank:  int32[n]  inverse permutation of sa
    lcp:   int32[n]  global LCP array (lcp[0] = 0)
    da:    int32[n]  document array
    c:     int32[n]  Muthukrishnan's C: previous position with same document
                     (-1 if none) — in SA order
    ilcp:  int32[n]  interleaved LCP array (Definition 1)
    """

    coll: Collection
    sa: np.ndarray
    rank: np.ndarray
    lcp: np.ndarray
    da: np.ndarray
    c: np.ndarray
    ilcp: np.ndarray

    @property
    def n(self) -> int:
        return self.coll.n

    @property
    def d(self) -> int:
        return self.coll.d


def build_suffix_data(coll: Collection) -> SuffixData:
    n = coll.n
    sa, tables = suffix_array_doubling(coll)
    rank = np.empty(n, dtype=np.int32)
    rank[sa] = np.arange(n, dtype=np.int32)

    # global LCP (adjacent SA entries)
    lcp = np.zeros(n, dtype=np.int32)
    if n > 1:
        lcp[1:] = pairwise_lcp(tables, sa[:-1], sa[1:], n)

    # document array
    da = (np.searchsorted(coll.doc_starts, sa, side="right") - 1).astype(np.int32)

    # C array: previous SA position with the same document
    c = np.full(n, -1, dtype=np.int32)
    order = np.argsort(da, kind="stable")  # groups docs, increasing SA pos
    da_sorted = da[order]
    prev = np.full(n, -1, dtype=np.int64)
    same_doc = np.zeros(n, dtype=bool)
    if n > 1:
        same_doc[1:] = da_sorted[1:] == da_sorted[:-1]
    prev[1:] = order[:-1]
    c[order] = np.where(same_doc, prev, -1).astype(np.int32)

    # ILCP via Lemma 1: within-document LCP against previous same-doc suffix
    ilcp = np.zeros(n, dtype=np.int32)
    has_prev = c >= 0
    if has_prev.any():
        cur_pos = sa[has_prev]
        prev_pos = sa[c[has_prev]]
        ilcp[has_prev] = pairwise_lcp(tables, prev_pos, cur_pos, n)

    return SuffixData(coll=coll, sa=sa, rank=rank, lcp=lcp, da=da, c=c, ilcp=ilcp)


# ---------------------------------------------------------------------------
# Naive oracles (used by tests and small-scale validation)
# ---------------------------------------------------------------------------


def naive_suffix_array(coll: Collection) -> np.ndarray:
    """O(n^2 log n) reference: plain suffix comparison of T (shared $)."""
    text = coll.text
    suffixes = sorted(range(coll.n), key=lambda i: tuple(text[i:]))
    return np.asarray(suffixes, dtype=np.int32)


def naive_lcp_of(coll: Collection, a: int, b: int) -> int:
    text = coll.text
    h = 0
    while a + h < coll.n and b + h < coll.n and text[a + h] == text[b + h]:
        h += 1
    return h


def encode_pattern(pattern) -> np.ndarray:
    """Map a query pattern to symbol space the same way concat_documents
    maps documents (strings byte-wise +1; ints +1)."""
    if isinstance(pattern, str):
        return np.frombuffer(pattern.encode("utf-8"), dtype=np.uint8).astype(
            np.int32
        ) + 1
    return np.asarray(pattern, dtype=np.int32) + 1


def sa_range_for_pattern(data: SuffixData, pattern) -> tuple[int, int]:
    """[lo, hi) SA range of suffixes prefixed by pattern (symbol space), by
    binary search on the suffix array (host-side reference; the CSA module
    provides the compressed backward search used at serving time)."""
    text = data.coll.text
    n = data.n
    pattern = np.asarray(pattern, dtype=np.int32)
    m = len(pattern)
    pat = tuple(int(x) for x in pattern)

    def prefix_of(i):
        seg = text[i : i + m]
        return tuple(int(x) for x in seg) + ((-1,) * (m - len(seg)))

    lo, hi = 0, n
    while lo < hi:
        mid = (lo + hi) // 2
        if prefix_of(int(data.sa[mid])) < pat:
            lo = mid + 1
        else:
            hi = mid
    start = lo
    lo, hi = start, n
    while lo < hi:
        mid = (lo + hi) // 2
        if prefix_of(int(data.sa[mid])) <= pat:
            lo = mid + 1
        else:
            hi = mid
    return start, lo
