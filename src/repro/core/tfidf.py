"""TF-IDF ranked multi-term queries (Section 6.5).

The index composition is exactly the paper's: RLCSA-style CSA for term
ranges, PDL (+F) as the abstract per-term inverted lists, and a Sadakane
counting structure for document frequencies.  Weights:

    w(D, Q) = sum_i f(tf(D, q_i)) * g(df(q_i)),
    f(tf) = tf,   g(df) = lg(d / max(df, 1)).

Two query engines:

* ``tfidf_topk`` — exact batched engine: every term's (doc, tf) pairs are
  fully aggregated (PDL decompress + brute merge, the strategy the paper
  found fastest for PDL merging), scores summed by document, ranked-AND
  filters documents that miss any term.  One jitted program; vmap over a
  padded batch of queries.

* ``tfidf_topk_incremental`` — the paper's k' = 2k, 4k, ... loop with
  lower/upper score bounds and early termination, host-orchestrated over
  jitted per-term extractions.  Returns the same top-k set (weights of a
  disjunctive early stop may be partial, as the paper notes).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32
from repro.core.csa import CSA
from repro.core.pdl import PDLIndex, pdl_doc_freqs, pdl_topk
from repro.core.sada import SadaCount, sada_count

BIG = np.iinfo(np.int32).max


def idf_weight(d: int, df):
    """g(df) = lg(d / max(df, 1))."""
    df = jnp.maximum(df, 1).astype(jnp.float32)
    return jnp.log2(jnp.float32(d) / df)


def tfidf_topk(
    pdl: PDLIndex,
    csa: CSA,
    sada: SadaCount,
    ranges,            # int32[T, 2] (lo, hi) per term; empty terms lo >= hi
    term_valid,        # bool[T]
    k: int,
    conjunctive: bool,
    max_buf: int = 2048,
    dfs=None,          # optional int32[T] per-term df override (sharded: global)
    n_docs: int | None = None,  # optional d override for g(df) (sharded: global)
):
    """Exact ranked-AND / ranked-OR top-k.  Returns (docs[k], scores[k]).

    Per-document scores are accumulated **term-major in a fixed order**:
    each candidate document looks up its integer tf in every term's sorted
    (doc, tf) list and folds ``tf * g(df)`` over the (static) term slots.
    A document's float score therefore depends only on its own per-term tf
    values and the weights — not on which other documents share the buffer
    — which is what makes the cross-shard merge bit-identical: a document
    scored inside one shard of a partitioned collection (with global ``dfs``
    / ``n_docs`` injected) produces the exact float the unsharded program
    produces.

    ``dfs``/``n_docs`` default to this index's own Sada counts and ``pdl.d``
    (the single-index behavior); the docs-sharded service passes the
    psum-merged global df and the global document count so idf weights are
    collection-wide.
    """
    ranges = as_i32(ranges)
    T = ranges.shape[0]
    term_valid = jnp.asarray(term_valid, dtype=jnp.bool_)

    def per_term(rng, tv):
        lo, hi = rng[0], rng[1]
        docs, tf, nseg = pdl_doc_freqs(pdl, csa, lo, hi, max_buf=max_buf)
        keep = tv & (jnp.arange(max_buf, dtype=IDX) < nseg)
        # rows stay sorted ascending: invalid tails are already BIG-padded
        docs = jnp.where(keep, docs, BIG)
        tf = jnp.where(keep, tf, 0)
        return docs, tf

    docs_t, tf_t = jax.vmap(per_term)(ranges, term_valid)   # [T, max_buf]
    if dfs is None:
        dfs = jax.vmap(lambda r: sada_count(sada, r[0], r[1]))(ranges)
    w = idf_weight(pdl.d if n_docs is None else n_docs, dfs)  # f32[T]

    # candidate set: each distinct doc across all term lists exactly once
    flat = docs_t.reshape(-1)
    M = flat.shape[0]
    s_docs = jnp.sort(flat)
    first = jnp.concatenate([jnp.ones(1, jnp.bool_), s_docs[1:] != s_docs[:-1]])
    cand_ok = first & (s_docs < BIG)
    cand = jnp.where(cand_ok, s_docs, BIG)

    # fixed-order weighted fold over the (static) term slots
    score = jnp.zeros(M, jnp.float32)
    seg_terms = jnp.zeros(M, IDX)
    for t in range(T):
        j = jnp.clip(jnp.searchsorted(docs_t[t], cand), 0, max_buf - 1)
        hit = (docs_t[t][j] == cand) & cand_ok
        score = score + jnp.where(hit, tf_t[t][j], 0).astype(jnp.float32) * w[t]
        seg_terms = seg_terms + hit.astype(IDX)

    seg_ok = cand_ok
    n_required = jnp.sum(term_valid.astype(IDX))
    if conjunctive:
        seg_ok = seg_ok & (seg_terms == n_required)

    return rank_topk_scores(cand, score, seg_ok, k)


def rank_topk_scores(docs, scores, ok, k: int):
    """Rank by (score desc, doc asc), take k: (docs[k] padded -1,
    scores[k] f32).  ``docs`` uses BIG for absent entries; the same total
    order the cross-shard k-way merge applies, so merging per-shard top-k
    lists through this function reproduces the unsharded ranking."""
    neg = jnp.where(ok, -scores, jnp.float32(np.inf))
    dkey = jnp.where(ok, docs, BIG)
    order = jnp.lexsort((dkey, neg))
    topd = dkey[order[:k]]
    tops = -neg[order[:k]]
    good = topd < BIG
    return (
        jnp.where(good, topd, -1).astype(IDX),
        jnp.where(good, tops, 0.0).astype(jnp.float32),
    )


def tfidf_topk_batch(
    pdl, csa, sada, ranges_batch, term_valid_batch, k, conjunctive, max_buf=2048,
    dfs_batch=None, n_docs: int | None = None,
):
    """vmap over a [Q, T, 2] batch of padded queries.  ``dfs_batch``
    (int32[Q, T]) and ``n_docs`` override the df / document-count inputs of
    the idf weight — the sharded engine's global-statistics injection."""
    ranges_batch = as_i32(ranges_batch)
    term_valid_batch = jnp.asarray(term_valid_batch, dtype=jnp.bool_)
    if dfs_batch is None:
        return jax.vmap(
            lambda r, tv: tfidf_topk(
                pdl, csa, sada, r, tv, k, conjunctive, max_buf, n_docs=n_docs
            )
        )(ranges_batch, term_valid_batch)
    return jax.vmap(
        lambda r, tv, df: tfidf_topk(
            pdl, csa, sada, r, tv, k, conjunctive, max_buf,
            dfs=df, n_docs=n_docs,
        )
    )(ranges_batch, term_valid_batch, as_i32(dfs_batch))


def term_ranges_batch(csa: CSA, patterns, lengths, *, use_kernel: bool | None = False):
    """Fused multi-term range finding for padded query batches.

    patterns: int32[Q, T, max_m] (term-padded, query-padded); lengths:
    int32[Q, T] with 0 marking absent term slots.  Returns
    (ranges int32[Q, T, 2], valid bool[Q, T]) — the exact input layout of
    ``tfidf_topk_batch`` — in one backward-search program (no host loop).

    ``use_kernel`` selects the range-search path exactly as the planner
    does: ``True`` launches the whole [Q*T] term batch as ONE fused Pallas
    backward search, ``False`` takes the XLA pair descent, ``None``
    auto-detects (kernel iff TPU).  All paths are bit-identical."""
    from repro.core.csa import csa_search_planned

    patterns = as_i32(patterns)
    lengths = as_i32(lengths)
    Q, T, m = patterns.shape
    lo, hi = csa_search_planned(
        csa, patterns.reshape(Q * T, m), lengths.reshape(-1),
        use_kernel=use_kernel,
    )
    hi = jnp.where(lengths.reshape(-1) > 0, hi, lo)
    ranges = jnp.stack([lo, hi], axis=-1).reshape(Q, T, 2)
    return ranges, lengths > 0


# ---------------------------------------------------------------------------
# The paper's incremental algorithm (Section 6.5 numbered loop)
# ---------------------------------------------------------------------------


def tfidf_topk_incremental(
    pdl: PDLIndex,
    csa: CSA,
    sada: SadaCount,
    ranges: np.ndarray,   # [T, 2] host array
    k: int,
    conjunctive: bool,
    max_buf: int = 2048,
):
    """Host-orchestrated k' doubling with score bounds.

    Step 1-6 of Section 6.5: extract k' docs per term (PDL lists are sorted
    by tf), maintain lower/upper bounds on w(D, Q), stop when the top-k set
    is provably stable.  Returns (docs list, lower-bound scores list).
    """
    T = len(ranges)
    d = pdl.d
    dfs = [int(sada_count(sada, int(lo), int(hi))) for lo, hi in ranges]
    gs = [float(np.log2(d / max(df, 1))) for df in dfs]

    # full per-term lists (tf-sorted); the incremental loop reads prefixes,
    # the conjunctive filter checks membership against the complete lists
    # ("completely decompressed document lists", step 2)
    full: list[tuple[np.ndarray, np.ndarray]] = []
    full_maps: list[dict[int, int]] = []
    for lo, hi in ranges:
        docs, tf = pdl_topk(pdl, csa, int(lo), int(hi), min(max_buf, pdl.d))
        docs = np.asarray(docs)
        tf = np.asarray(tf)
        keep = docs >= 0
        full.append((docs[keep], tf[keep]))
        full_maps.append({int(a): int(b) for a, b in zip(docs[keep], tf[keep])})

    kp = 2 * k
    while True:
        # step 1: extract k' more documents per term
        prefix: dict[int, dict[int, int]] = {}
        next_tf = []
        for t in range(T):
            docs, tf = full[t]
            head = min(kp, len(docs))
            for j in range(head):
                prefix.setdefault(int(docs[j]), {})[t] = int(tf[j])
            next_tf.append(int(tf[head]) if head < len(docs) else 0)

        # steps 3-4: lower / upper bounds for every extracted document
        lower, upper = {}, {}
        for doc, seen in prefix.items():
            lower[doc] = sum(seen.get(t, 0) * gs[t] for t in range(T))
            upper[doc] = sum(
                (seen[t] if t in seen else next_tf[t]) * gs[t] for t in range(T)
            )

        # step 2: conjunctive filter against complete lists
        if conjunctive:
            cand = {
                doc: w
                for doc, w in lower.items()
                if all(doc in full_maps[t] for t in range(T))
            }
        else:
            cand = lower

        ranked = sorted(cand.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
        exhausted = all(kp >= len(full[t][0]) for t in range(T))
        if exhausted:
            return [doc for doc, _ in ranked], [w for _, w in ranked]

        # steps 5-6: early termination when the top-k set cannot change
        kth = ranked[k - 1][1] if len(ranked) >= k else -np.inf
        unseen_upper = sum(next_tf[t] * gs[t] for t in range(T))
        top_set = {doc for doc, _ in ranked}
        seen_safe = all(
            upper[doc] <= kth for doc in cand if doc not in top_set
        )
        if len(ranked) >= k and unseen_upper <= kth and seen_safe:
            return [doc for doc, _ in ranked], [w for _, w in ranked]
        kp *= 2
