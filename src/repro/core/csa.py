"""Compressed suffix array: FM-index with run-length (RLCSA) accounting.

The paper's experiments all sit on top of the RLCSA (Makinen et al 2010):
``search(m)`` finds the SA range of a pattern by backward search, and
``lookup(n)`` retrieves SA[i] by LF-walking to a sampled position.  We
implement the same functional interface:

* backward search over a wavelet matrix of the BWT — a fixed-length
  ``lax.scan`` over pattern symbols (masked for padding), so a *batch* of
  patterns is one vectorized program;
* locate via LF-walk with text-position sampling; every document start is
  additionally sampled, which bounds the walk by the sample rate and stops
  it at document boundaries.  Under the shared-$ plain-suffix-array
  semantics (see repro.core.suffix) SA is the suffix array of the single
  string T, so the LF identity is exact — terminators are ordinary symbols.

Space accounting: the working set is the plain wavelet matrix (TPU layout);
``modeled_bits_rlcsa`` reports the run-length compressed size the paper's
RLCSA would use (rho_bwt runs), which is what the space axes of Figures
6-10 show for the CSA component.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, ceil_log2, pytree_dataclass
from repro.core.suffix import SuffixData
from repro.succinct.bitvector import SparseBitvector, sparse_from_positions
from repro.succinct.wavelet import WaveletMatrix, wm_access, wm_build, wm_rank


@pytree_dataclass(meta=("n", "d", "sigma", "sample_rate", "bwt_runs"))
class CSA:
    wm: WaveletMatrix          # wavelet matrix over the BWT
    counts: jnp.ndarray        # int32[sigma+1]: symbols strictly < c
    sampled: SparseBitvector   # SA positions i whose SA[i] is sampled
    samples: jnp.ndarray       # int32[s]: SA[i] for sampled i, in SA order
    doc_bv: SparseBitvector    # text positions of document starts (bitvector B)
    n: int
    d: int
    sigma: int
    sample_rate: int
    bwt_runs: int

    # -- space accounting ---------------------------------------------------

    def modeled_bits_rlcsa(self) -> int:
        """rho(lg sigma + 2 lg(n/rho)) + samples — the RLCSA model."""
        rho = max(1, self.bwt_runs)
        per_run = ceil_log2(self.sigma) + 2 * max(1, ceil_log2(max(2, self.n // rho)))
        sample_bits = int(self.samples.shape[0]) * ceil_log2(max(2, self.n))
        return rho * per_run + sample_bits

    def modeled_bits_plain_fm(self) -> int:
        return self.n * ceil_log2(self.sigma) + int(self.samples.shape[0]) * ceil_log2(
            max(2, self.n)
        )


def build_csa(data: SuffixData, sample_rate: int = 16) -> CSA:
    coll = data.coll
    n, d = coll.n, coll.d
    sa = data.sa
    bwt = coll.text[(sa - 1) % n]

    wm = wm_build(bwt, coll.sigma)

    # counts[c] = number of symbols strictly smaller than c
    hist = np.bincount(coll.text, minlength=coll.sigma + 1)
    counts = np.zeros(coll.sigma + 1, dtype=np.int32)
    counts[1:] = np.cumsum(hist)[:-1].astype(np.int32)

    # sampling: SA[i] % rate == 0, plus every document start
    text_sampled = (sa % sample_rate == 0) | np.isin(sa, coll.doc_starts)
    marked_sa_positions = np.flatnonzero(text_sampled)
    samples = sa[marked_sa_positions].astype(np.int32)

    runs = int(1 + np.count_nonzero(np.diff(bwt))) if n else 0

    return CSA(
        wm=wm,
        counts=jnp.asarray(counts),
        sampled=sparse_from_positions(marked_sa_positions, n),
        samples=jnp.asarray(samples),
        doc_bv=sparse_from_positions(coll.doc_starts, n),
        n=n,
        d=d,
        sigma=coll.sigma,
        sample_rate=sample_rate,
        bwt_runs=runs,
    )


# ---------------------------------------------------------------------------
# search(m): backward search (batched)
# ---------------------------------------------------------------------------


def csa_symbol_bounds(csa: CSA, c):
    """Input hardening for one backward-search step (shared by every search
    path — the scalar scan, the batched pair descent, and the reference
    loop all route through this one validator).

    A symbol outside ``[0, sigma)`` cannot occur: the range collapses to
    the empty range at the symbol's lexicographic insertion point (0 below
    the alphabet, n above it), matching the host binary search's
    convention, and the clamped symbol ``cc`` keeps every downstream gather
    in bounds.  Returns ``(cc, c_ok, oob)``: the clamped symbol, the
    validity mask, and the collapse point.
    """
    c = as_i32(c)
    c_ok = (c >= 0) & (c < csa.sigma)
    cc = jnp.clip(c, 0, csa.sigma - 1)
    oob = jnp.where(c < 0, 0, csa.n).astype(IDX)
    return cc, c_ok, oob


def csa_search(csa: CSA, pattern, length):
    """SA range [lo, hi) of suffixes prefixed by ``pattern[:length]``.

    pattern: int32[max_m] (padded), length: scalar.  Fully traced: suitable
    for vmap over a batch of padded patterns.
    """
    pattern = as_i32(pattern)
    max_m = pattern.shape[0]
    length = as_i32(length)

    def body(carry, t):
        lo, hi = carry
        # process symbols right-to-left; slot t handles pattern[length-1-t]
        j = length - 1 - t
        active = (t < length) & (lo < hi)
        c = pattern[jnp.clip(j, 0, max_m - 1)]
        cc, c_ok, oob = csa_symbol_bounds(csa, c)
        nlo = jnp.where(c_ok, csa.counts[cc] + wm_rank(csa.wm, cc, lo), oob)
        nhi = jnp.where(c_ok, csa.counts[cc] + wm_rank(csa.wm, cc, hi), oob)
        lo = jnp.where(active, nlo, lo)
        hi = jnp.where(active, nhi, hi)
        return (lo, hi), None

    (lo, hi), _ = jax.lax.scan(
        body, (as_i32(0), as_i32(csa.n)), jnp.arange(max_m, dtype=IDX)
    )
    return lo, jnp.maximum(lo, hi)


def csa_search_batch(csa: CSA, patterns, lengths):
    """patterns: int32[Q, max_m]; lengths: int32[Q] -> (lo[Q], hi[Q])."""
    return jax.vmap(lambda p, l: csa_search(csa, p, l))(
        as_i32(patterns), as_i32(lengths)
    )


def csa_search_planned(csa: CSA, patterns, lengths, *, use_kernel: bool | None = None,
                       block_q: int = 256, interpret: bool | None = None):
    """Backward search written batch-first for the serving planner.

    Same integers as ``csa_search_batch``, but computed over [B] range
    arrays with both SA-range boundaries riding ONE wavelet descent per
    symbol step (``wm_rank_pair_batch``) — half the per-level rank gathers
    of two independent ``wm_rank_batch`` descents.

    ``use_kernel`` selects the execution path:
      * ``None``  — auto: the fused Pallas kernel on TPU, XLA elsewhere;
      * ``True``  — force the fused kernel (``repro.kernels.backward_search``;
        one ``pallas_call`` for the whole batched search, interpret mode
        off-TPU unless ``interpret`` says otherwise);
      * ``False`` — force the XLA pair-descent path.
    """
    patterns = as_i32(patterns)
    lengths = as_i32(lengths)
    B, max_m = patterns.shape

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.ops import backward_search

        return backward_search(
            csa.wm.words, csa.wm.ones_prefix, csa.wm.zcount,
            csa.counts[: csa.sigma] - csa.wm.sym_starts,
            patterns, lengths,
            n=csa.n, sigma=csa.sigma, block_q=block_q, interpret=interpret,
        )

    from repro.succinct.wavelet import wm_rank_pair_batch

    rows = jnp.arange(B, dtype=IDX)

    def body(carry, t):
        lo, hi = carry
        j = lengths - 1 - t
        active = (t < lengths) & (lo < hi)
        c = patterns[rows, jnp.clip(j, 0, max_m - 1)]
        cc, c_ok, oob = csa_symbol_bounds(csa, c)
        rlo, rhi = wm_rank_pair_batch(csa.wm, cc, lo, hi)
        lo = jnp.where(active, jnp.where(c_ok, csa.counts[cc] + rlo, oob), lo)
        hi = jnp.where(active, jnp.where(c_ok, csa.counts[cc] + rhi, oob), hi)
        return (lo, hi), None

    (lo, hi), _ = jax.lax.scan(
        body,
        (jnp.zeros(B, IDX), jnp.full(B, csa.n, IDX)),
        jnp.arange(max_m, dtype=IDX),
    )
    return lo, jnp.maximum(lo, hi)


# ---------------------------------------------------------------------------
# lookup(n): locate SA[i] by LF-walk to a sample (batched)
# ---------------------------------------------------------------------------


def _lf(csa: CSA, j):
    c = wm_access(csa.wm, j)
    return csa.counts[c] + wm_rank(csa.wm, c, j)


def csa_lookup(csa: CSA, i):
    """SA[i] for a single (traced) index; O(sample_rate) LF steps."""

    def cond(carry):
        j, steps, done = carry
        return ~done

    def body(carry):
        j, steps, _ = carry
        is_sampled = csa.sampled.get(j) == 1
        nj = jnp.where(is_sampled, j, _lf(csa, j))
        nsteps = jnp.where(is_sampled, steps, steps + 1)
        return (nj, nsteps, is_sampled)

    j, steps, _ = jax.lax.while_loop(cond, body, (as_i32(i), as_i32(0), jnp.bool_(False)))
    base = csa.samples[csa.sampled.rank1(j)]
    return (base + steps).astype(IDX)


def csa_lookup_batch(csa: CSA, idx):
    return jax.vmap(lambda i: csa_lookup(csa, i))(as_i32(idx))


def csa_doc_of(csa: CSA, text_pos):
    """DA[i] given SA[i]: rank over the document-start bitvector B."""
    return csa.doc_bv.rank1(as_i32(text_pos) + 1) - 1


def csa_da_at(csa: CSA, i):
    """DA[i] = rank_B(SA[i]) — the Sadakane replacement for a stored DA."""
    return csa_doc_of(csa, csa_lookup(csa, i))


def csa_locate_range(csa: CSA, lo, max_out: int):
    """Locate SA[lo : lo + max_out] (masked by caller against hi)."""
    idx = as_i32(lo) + jnp.arange(max_out, dtype=IDX)
    idx = jnp.minimum(idx, csa.n - 1)
    return csa_lookup_batch(csa, idx)
