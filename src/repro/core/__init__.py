"""The paper's contribution: document retrieval on repetitive string
collections (Gagie et al.).

Modules:
  suffix   — suffix array / LCP / document array / ILCP construction
  csa      — FM-index (RLCSA-accounted) backward search + locate
  ilcp     — Interleaved LCP: run-length listing + counting   (Section 3)
  pdl      — Precomputed Document Lists: listing + top-k      (Section 4)
  sada     — compressed Sadakane document counting            (Section 5)
  listing  — brute-force and Sada-C baselines                 (Section 6.2.1)
  tfidf    — ranked multi-term AND/OR queries                 (Section 6.5)
"""

from repro.core.suffix import (
    Collection,
    SuffixData,
    build_suffix_data,
    concat_documents,
    encode_pattern,
    sa_range_for_pattern,
)
from repro.core.csa import CSA, build_csa, csa_search, csa_search_batch

__all__ = [
    "Collection",
    "SuffixData",
    "build_suffix_data",
    "concat_documents",
    "encode_pattern",
    "sa_range_for_pattern",
    "CSA",
    "build_csa",
    "csa_search",
    "csa_search_batch",
]
