"""Wavelet matrix over small-alphabet sequences.

The paper uses wavelet trees in three places:
  * the balanced wavelet tree over the document array DA (the WT document
    lister of Valimaki & Makinen 2007 / Navarro et al 2014 baseline),
  * rank_c over the BWT inside the CSA backward search,
  * the *skewed* wavelet tree over VILCP for ILCP document counting (Sec 3.4).

We implement the pointerless *wavelet matrix* (Claude, Navarro & Ordonez
2015), which is rank/select-equivalent to the wavelet tree, has identical
space, and maps better onto batched TPU dataflow: each level is one global
bitvector (one gather per level, no per-node offsets).  The skewed-tree
*query* of Section 3.4 is realised by the equivalent value-loop over
wavelet-matrix ranks plus the L' run-length bitmap (see repro.core.ilcp);
the skewed shape's O(m)-node guarantee becomes an O(m lg lambda) batched
guarantee here — recorded in DESIGN.md Section 6.

Conventions: sequence values in [0, sigma); all ranks half-open as in
repro.succinct.bitvector.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, ceil_log2, pytree_dataclass
from repro.succinct.bitvector import plain_from_bits


@pytree_dataclass(meta=("n", "sigma", "levels"))
class WaveletMatrix:
    """levels stacked bitvectors; level 0 tests the MSB.

    words:        uint32[L, W+1]
    ones_prefix:  int32[L, W+1]
    zcount:       int32[L]      number of zeros at each level
    sym_starts:   int32[sigma]  position where symbol c's block starts at the
                                (virtual) bottom level — the full descent of
                                position 0 following c's bits.  rank_c(S, i)
                                is then descend(i) - sym_starts[c]: ONE
                                carried position per query position instead
                                of the classic (start, end) pair, which is
                                what lets the pair-descent rank and the fused
                                backward-search kernel halve their gathers.
    """

    words: jnp.ndarray
    ones_prefix: jnp.ndarray
    zcount: jnp.ndarray
    sym_starts: jnp.ndarray
    n: int
    sigma: int
    levels: int

    def _rank1_level(self, lvl, i):
        i = as_i32(i)
        w = i >> 5
        off = (i & 31).astype(jnp.uint32)
        word = self.words[lvl, w]
        mask = (jnp.uint32(1) << off) - jnp.uint32(1)
        return self.ones_prefix[lvl, w] + jax.lax.population_count(word & mask).astype(IDX)

    def _rank0_level(self, lvl, i):
        return as_i32(i) - self._rank1_level(lvl, i)


def wm_build(seq, sigma: int | None = None) -> WaveletMatrix:
    """Host-side build (offline, like every index build in the paper)."""
    seq = np.asarray(seq, dtype=np.int64)
    n = int(seq.shape[0])
    if sigma is None:
        sigma = int(seq.max()) + 1 if n else 1
    levels = max(1, ceil_log2(max(sigma, 2)))
    cur = seq.copy()
    words_l, prefix_l, zc = [], [], []
    for lvl in range(levels):
        shift = levels - 1 - lvl
        bits = (cur >> shift) & 1
        bv = plain_from_bits(bits)
        words_l.append(np.asarray(bv.words))
        prefix_l.append(np.asarray(bv.ones_prefix))
        zc.append(int(n - bits.sum()))
        # stable partition: zeros first
        cur = np.concatenate([cur[bits == 0], cur[bits == 1]])

    # per-symbol block starts: descend position 0 for every c simultaneously
    def host_rank1(lvl, pos):
        w = pos >> 5
        mask = (np.uint32(1) << (pos & 31).astype(np.uint32)) - np.uint32(1)
        masked = words_l[lvl][w] & mask
        pc = np.array([int(v).bit_count() for v in masked], dtype=np.int64)
        return prefix_l[lvl][w].astype(np.int64) + pc

    syms = np.arange(sigma, dtype=np.int64)
    s = np.zeros(sigma, dtype=np.int64)
    for lvl in range(levels):
        bit = (syms >> (levels - 1 - lvl)) & 1
        r1 = host_rank1(lvl, s)
        s = np.where(bit == 0, s - r1, zc[lvl] + r1)

    return WaveletMatrix(
        words=jnp.asarray(np.stack(words_l)),
        ones_prefix=jnp.asarray(np.stack(prefix_l)),
        zcount=jnp.asarray(np.asarray(zc, dtype=np.int32)),
        sym_starts=jnp.asarray(s.astype(np.int32)),
        n=n,
        sigma=int(sigma),
        levels=levels,
    )


def wm_rank(wm: WaveletMatrix, c, i):
    """rank_c(S, i): occurrences of symbol c in S[0, i).  Traced c, i ok."""
    c = as_i32(c)

    def body(lvl, carry):
        lo, hi = carry  # block start and mapped prefix end
        bit = (c >> (wm.levels - 1 - lvl)) & 1
        z = wm.zcount[lvl]
        lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
        lo1, hi1 = z + (lo - lo0), z + (hi - hi0)
        lo = jnp.where(bit == 0, lo0, lo1)
        hi = jnp.where(bit == 0, hi0, hi1)
        return (lo, hi)

    lo, hi = jax.lax.fori_loop(0, wm.levels, body, (as_i32(0), as_i32(i)))
    return (hi - lo).astype(IDX)


def wm_rank_batch(wm: WaveletMatrix, c, i, *, use_kernel: bool = False,
                  block_q: int = 1024):
    """Batched rank_c over int32[B] symbol/position arrays.

    With ``use_kernel=False`` this is ``wm_rank`` elementwise (every op in
    the descent is already dense).  With ``use_kernel=True`` each level's
    two prefix ranks go through the Pallas bitvector-rank kernel
    (repro.kernels.rank) as one fused 2B-query stream per level — the TPU
    hot path for the serving planner's range search.  Both paths compute
    the identical integers."""
    c = as_i32(c)
    i = as_i32(i)
    B = i.shape[0]

    if use_kernel:
        from repro.kernels.ops import rank as rank_kernel

        def body(lvl, carry):
            lo, hi = carry
            bit = (c >> (wm.levels - 1 - lvl)) & 1
            z = wm.zcount[lvl]
            r1 = rank_kernel(
                wm.words[lvl], wm.ones_prefix[lvl], jnp.concatenate([lo, hi]),
                block_q=block_q,
            )
            lo = jnp.where(bit == 0, lo - r1[:B], z + r1[:B])
            hi = jnp.where(bit == 0, hi - r1[B:], z + r1[B:])
            return (lo, hi)

    else:

        def body(lvl, carry):
            lo, hi = carry
            bit = (c >> (wm.levels - 1 - lvl)) & 1
            z = wm.zcount[lvl]
            lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
            lo = jnp.where(bit == 0, lo0, z + (lo - lo0))
            hi = jnp.where(bit == 0, hi0, z + (hi - hi0))
            return (lo, hi)

    lo, hi = jax.lax.fori_loop(
        0, wm.levels, body, (jnp.zeros(B, IDX), as_i32(i))
    )
    return (hi - lo).astype(IDX)


def wm_descend(wm: WaveletMatrix, c, i):
    """Descend position(s) ``i`` along symbol ``c``'s bit path.

    One rank gather per level per position.  ``rank_c(S, i)`` equals
    ``wm_descend(wm, c, i) - wm.sym_starts[c]`` — the block-start carry of
    the classic two-position descent is precomputed at build time, so a
    rank costs half the gathers of ``wm_rank``.  c must be in [0, sigma);
    c and i may be scalars or equal-shape arrays (elementwise).
    """
    c = as_i32(c)

    def body(lvl, p):
        bit = (c >> (wm.levels - 1 - lvl)) & 1
        r1 = wm._rank1_level(lvl, p)
        return jnp.where(bit == 0, p - r1, wm.zcount[lvl] + r1)

    return jax.lax.fori_loop(0, wm.levels, body, as_i32(i))


def wm_rank_pair(wm: WaveletMatrix, c, lo, hi):
    """Fused boundary-pair rank: (rank_c(S, lo), rank_c(S, hi)).

    Both positions ride one descent along c's bit path — 2 rank gathers per
    level against the 4 of two independent ``wm_rank`` calls.  This is the
    XLA-fallback core of the backward-search step (both SA-range boundaries
    share the pattern symbol) and of the ILCP counting value loop.  c must
    be in [0, sigma); all args may be scalars or equal-shape arrays.
    """
    c = as_i32(c)

    def body(lvl, pq):
        p, q = pq
        bit = (c >> (wm.levels - 1 - lvl)) & 1
        z = wm.zcount[lvl]
        r1p = wm._rank1_level(lvl, p)
        r1q = wm._rank1_level(lvl, q)
        p = jnp.where(bit == 0, p - r1p, z + r1p)
        q = jnp.where(bit == 0, q - r1q, z + r1q)
        return (p, q)

    dlo, dhi = jax.lax.fori_loop(0, wm.levels, body, (as_i32(lo), as_i32(hi)))
    start = wm.sym_starts[c]
    return (dlo - start).astype(IDX), (dhi - start).astype(IDX)


def wm_rank_pair_batch(wm: WaveletMatrix, c, lo, hi):
    """Batched ``wm_rank_pair`` over int32[B] symbol/position arrays —
    alias kept separate so call sites document batch-first intent."""
    return wm_rank_pair(wm, c, lo, hi)


def wm_access(wm: WaveletMatrix, i):
    """S[i]."""

    def body(lvl, carry):
        pos, val = carry
        w = pos >> 5
        bit = ((wm.words[lvl, w] >> (pos & 31).astype(jnp.uint32)) & 1).astype(IDX)
        z = wm.zcount[lvl]
        r1 = wm._rank1_level(lvl, pos)
        pos0 = pos - r1           # rank0(pos)
        pos = jnp.where(bit == 0, pos0, z + r1)
        val = (val << 1) | bit
        return (pos, val)

    _, val = jax.lax.fori_loop(0, wm.levels, body, (as_i32(i), as_i32(0)))
    return val


def wm_count_less(wm: WaveletMatrix, lo, hi, m):
    """Number of positions p in [lo, hi) with S[p] < m.  Traced args ok;
    lo/hi/m may also be equal-shape arrays (elementwise batch).

    Both range boundaries ride one descent along m's bit path (the same
    pair-descent fusion as ``wm_rank_pair``): 2 rank gathers per level.
    """
    m = as_i32(m)

    def body(lvl, carry):
        lo, hi, acc = carry
        bit = (m >> (wm.levels - 1 - lvl)) & 1
        z = wm.zcount[lvl]
        lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
        lo1, hi1 = z + (lo - lo0), z + (hi - hi0)
        # if the m-bit is 1, every value with 0 at this level (same prefix)
        # is < m: add the size of the left block, descend right.
        acc = acc + jnp.where(bit == 1, hi0 - lo0, 0)
        lo = jnp.where(bit == 0, lo0, lo1)
        hi = jnp.where(bit == 0, hi0, hi1)
        return (lo, hi, acc)

    big = m >= wm.sigma
    lo_, hi_, acc = jax.lax.fori_loop(
        0, wm.levels, body, (as_i32(lo), as_i32(hi), as_i32(0))
    )
    return jnp.where(big, as_i32(hi) - as_i32(lo), acc)


def wm_symbol_range(wm: WaveletMatrix, c, lo, hi):
    """Occurrence-rank interval of symbol c within S[lo, hi).

    Returns (a, b): the occurrences of c inside [lo, hi) are the a-th .. b-1-th
    occurrences of c in the whole sequence.  This is the wavelet-tree "arrive
    at leaf c with an interval" operation used by the skewed-tree counting of
    Section 3.4; combined with the L' bitmap it weights run heads by lengths.
    """
    a = wm_rank(wm, c, lo)
    b = wm_rank(wm, c, hi)
    return a, b


def wm_modeled_bits(wm: WaveletMatrix) -> int:
    """n*ceil(lg sigma) + o(...) — plain-bitvector levels (Grossi et al 2003)."""
    per_level = wm.n + max(1, wm.n // 8)
    return wm.levels * per_level + 64 * wm.levels
