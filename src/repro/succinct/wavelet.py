"""Wavelet matrix over small-alphabet sequences.

The paper uses wavelet trees in three places:
  * the balanced wavelet tree over the document array DA (the WT document
    lister of Valimaki & Makinen 2007 / Navarro et al 2014 baseline),
  * rank_c over the BWT inside the CSA backward search,
  * the *skewed* wavelet tree over VILCP for ILCP document counting (Sec 3.4).

We implement the pointerless *wavelet matrix* (Claude, Navarro & Ordonez
2015), which is rank/select-equivalent to the wavelet tree, has identical
space, and maps better onto batched TPU dataflow: each level is one global
bitvector (one gather per level, no per-node offsets).  The skewed-tree
*query* of Section 3.4 is realised by the equivalent value-loop over
wavelet-matrix ranks plus the L' run-length bitmap (see repro.core.ilcp);
the skewed shape's O(m)-node guarantee becomes an O(m lg lambda) batched
guarantee here — recorded in DESIGN.md Section 6.

Conventions: sequence values in [0, sigma); all ranks half-open as in
repro.succinct.bitvector.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, ceil_log2, pytree_dataclass
from repro.succinct.bitvector import plain_from_bits


@pytree_dataclass(meta=("n", "sigma", "levels"))
class WaveletMatrix:
    """levels stacked bitvectors; level 0 tests the MSB.

    words:        uint32[L, W+1]
    ones_prefix:  int32[L, W+1]
    zcount:       int32[L]      number of zeros at each level
    """

    words: jnp.ndarray
    ones_prefix: jnp.ndarray
    zcount: jnp.ndarray
    n: int
    sigma: int
    levels: int

    def _rank1_level(self, lvl, i):
        i = as_i32(i)
        w = i >> 5
        off = (i & 31).astype(jnp.uint32)
        word = self.words[lvl, w]
        mask = (jnp.uint32(1) << off) - jnp.uint32(1)
        return self.ones_prefix[lvl, w] + jax.lax.population_count(word & mask).astype(IDX)

    def _rank0_level(self, lvl, i):
        return as_i32(i) - self._rank1_level(lvl, i)


def wm_build(seq, sigma: int | None = None) -> WaveletMatrix:
    """Host-side build (offline, like every index build in the paper)."""
    seq = np.asarray(seq, dtype=np.int64)
    n = int(seq.shape[0])
    if sigma is None:
        sigma = int(seq.max()) + 1 if n else 1
    levels = max(1, ceil_log2(max(sigma, 2)))
    cur = seq.copy()
    words_l, prefix_l, zc = [], [], []
    for lvl in range(levels):
        shift = levels - 1 - lvl
        bits = (cur >> shift) & 1
        bv = plain_from_bits(bits)
        words_l.append(np.asarray(bv.words))
        prefix_l.append(np.asarray(bv.ones_prefix))
        zc.append(int(n - bits.sum()))
        # stable partition: zeros first
        cur = np.concatenate([cur[bits == 0], cur[bits == 1]])
    return WaveletMatrix(
        words=jnp.asarray(np.stack(words_l)),
        ones_prefix=jnp.asarray(np.stack(prefix_l)),
        zcount=jnp.asarray(np.asarray(zc, dtype=np.int32)),
        n=n,
        sigma=int(sigma),
        levels=levels,
    )


def wm_rank(wm: WaveletMatrix, c, i):
    """rank_c(S, i): occurrences of symbol c in S[0, i).  Traced c, i ok."""
    c = as_i32(c)

    def body(lvl, carry):
        lo, hi = carry  # block start and mapped prefix end
        bit = (c >> (wm.levels - 1 - lvl)) & 1
        z = wm.zcount[lvl]
        lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
        lo1, hi1 = z + (lo - lo0), z + (hi - hi0)
        lo = jnp.where(bit == 0, lo0, lo1)
        hi = jnp.where(bit == 0, hi0, hi1)
        return (lo, hi)

    lo, hi = jax.lax.fori_loop(0, wm.levels, body, (as_i32(0), as_i32(i)))
    return (hi - lo).astype(IDX)


def wm_rank_batch(wm: WaveletMatrix, c, i, *, use_kernel: bool = False,
                  block_q: int = 1024):
    """Batched rank_c over int32[B] symbol/position arrays.

    With ``use_kernel=False`` this is ``wm_rank`` elementwise (every op in
    the descent is already dense).  With ``use_kernel=True`` each level's
    two prefix ranks go through the Pallas bitvector-rank kernel
    (repro.kernels.rank) as one fused 2B-query stream per level — the TPU
    hot path for the serving planner's range search.  Both paths compute
    the identical integers."""
    c = as_i32(c)
    i = as_i32(i)
    B = i.shape[0]

    if use_kernel:
        from repro.kernels.ops import rank as rank_kernel

        def body(lvl, carry):
            lo, hi = carry
            bit = (c >> (wm.levels - 1 - lvl)) & 1
            z = wm.zcount[lvl]
            r1 = rank_kernel(
                wm.words[lvl], wm.ones_prefix[lvl], jnp.concatenate([lo, hi]),
                block_q=block_q,
            )
            lo = jnp.where(bit == 0, lo - r1[:B], z + r1[:B])
            hi = jnp.where(bit == 0, hi - r1[B:], z + r1[B:])
            return (lo, hi)

    else:

        def body(lvl, carry):
            lo, hi = carry
            bit = (c >> (wm.levels - 1 - lvl)) & 1
            z = wm.zcount[lvl]
            lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
            lo = jnp.where(bit == 0, lo0, z + (lo - lo0))
            hi = jnp.where(bit == 0, hi0, z + (hi - hi0))
            return (lo, hi)

    lo, hi = jax.lax.fori_loop(
        0, wm.levels, body, (jnp.zeros(B, IDX), as_i32(i))
    )
    return (hi - lo).astype(IDX)


def wm_access(wm: WaveletMatrix, i):
    """S[i]."""

    def body(lvl, carry):
        pos, val = carry
        w = pos >> 5
        bit = ((wm.words[lvl, w] >> (pos & 31).astype(jnp.uint32)) & 1).astype(IDX)
        z = wm.zcount[lvl]
        r1 = wm._rank1_level(lvl, pos)
        pos0 = pos - r1           # rank0(pos)
        pos = jnp.where(bit == 0, pos0, z + r1)
        val = (val << 1) | bit
        return (pos, val)

    _, val = jax.lax.fori_loop(0, wm.levels, body, (as_i32(i), as_i32(0)))
    return val


def wm_count_less(wm: WaveletMatrix, lo, hi, m):
    """Number of positions p in [lo, hi) with S[p] < m.  Traced args ok."""
    m = as_i32(m)

    def body(lvl, carry):
        lo, hi, acc = carry
        bit = (m >> (wm.levels - 1 - lvl)) & 1
        z = wm.zcount[lvl]
        lo0, hi0 = wm._rank0_level(lvl, lo), wm._rank0_level(lvl, hi)
        lo1, hi1 = z + (lo - lo0), z + (hi - hi0)
        # if the m-bit is 1, every value with 0 at this level (same prefix)
        # is < m: add the size of the left block, descend right.
        acc = acc + jnp.where(bit == 1, hi0 - lo0, 0)
        lo = jnp.where(bit == 0, lo0, lo1)
        hi = jnp.where(bit == 0, hi0, hi1)
        return (lo, hi, acc)

    big = m >= wm.sigma
    lo_, hi_, acc = jax.lax.fori_loop(
        0, wm.levels, body, (as_i32(lo), as_i32(hi), as_i32(0))
    )
    return jnp.where(big, as_i32(hi) - as_i32(lo), acc)


def wm_symbol_range(wm: WaveletMatrix, c, lo, hi):
    """Occurrence-rank interval of symbol c within S[lo, hi).

    Returns (a, b): the occurrences of c inside [lo, hi) are the a-th .. b-1-th
    occurrences of c in the whole sequence.  This is the wavelet-tree "arrive
    at leaf c with an interval" operation used by the skewed-tree counting of
    Section 3.4; combined with the L' bitmap it weights run heads by lengths.
    """
    a = wm_rank(wm, c, lo)
    b = wm_rank(wm, c, hi)
    return a, b


def wm_modeled_bits(wm: WaveletMatrix) -> int:
    """n*ceil(lg sigma) + o(...) — plain-bitvector levels (Grossi et al 2003)."""
    per_level = wm.n + max(1, wm.n // 8)
    return wm.levels * per_level + 64 * wm.levels
