"""Range-minimum queries (leftmost-minimum semantics).

The paper relies on RMQ twice:
  * Muthukrishnan/Sadakane document listing recursion over C (Sada-C) and
    over the run heads VILCP (Sada-I, Section 3.3) — correctness of the
    V-marking optimization *requires* the leftmost minimum (Lemma 3).

We use a sparse table (power-of-two windows).  On a scalar CPU the paper
chooses the 2n-bit Fischer-Heun structure; on TPU a query must be a small
fixed number of gathers, and the sparse table gives exactly two gathers and
one compare per query with perfect vmap batching.  The space trade
(n lg n words vs 2n bits) is reported in benchmarks via ``modeled_bits``
both ways, so the paper's space accounting stays visible (DESIGN.md Sec 6).

The table stores *argmin positions*; ties resolve to the leftmost, which the
listing proof (Lemma 3) depends on.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX, as_i32, floor_log2, pytree_dataclass


@pytree_dataclass(meta=("n", "levels"))
class SparseTableRMQ:
    """table[k, i] = argmin of values[i : i + 2^k] (leftmost).

    values: int32[n]        (kept for comparisons at query time)
    table:  int32[L, n]
    """

    values: jnp.ndarray
    table: jnp.ndarray
    n: int
    levels: int


def rmq_build(values) -> SparseTableRMQ:
    values = np.asarray(values, dtype=np.int32)
    n = int(values.shape[0])
    if n == 0:
        return SparseTableRMQ(
            values=jnp.zeros((1,), IDX), table=jnp.zeros((1, 1), IDX), n=0, levels=1
        )
    levels = floor_log2(n) + 1
    table = np.zeros((levels, n), dtype=np.int32)
    table[0] = np.arange(n, dtype=np.int32)
    for k in range(1, levels):
        half = 1 << (k - 1)
        left = table[k - 1]
        right_idx = np.minimum(np.arange(n) + half, n - 1)
        right = table[k - 1][right_idx]
        # leftmost tie-break: strict less required to move to the right arg
        take_right = values[right] < values[left]
        table[k] = np.where(take_right, right, left)
    return SparseTableRMQ(
        values=jnp.asarray(values), table=jnp.asarray(table), n=n, levels=levels
    )


def _floor_log2_jnp(x):
    """floor(lg x) for x >= 1 as a traced value (31 - clz)."""
    x = as_i32(x)
    return 31 - jax.lax.clz(x)


def rmq_query(rmq: SparseTableRMQ, lo, hi):
    """Leftmost argmin of values[lo..hi] inclusive.  Traced lo/hi ok.

    Returns lo for empty/invalid ranges (callers guard on lo <= hi).
    """
    lo = as_i32(lo)
    hi = as_i32(hi)
    span = jnp.maximum(hi - lo + 1, 1)
    k = _floor_log2_jnp(span)
    k = jnp.clip(k, 0, rmq.levels - 1)
    a = rmq.table[k, lo]
    b = rmq.table[k, jnp.maximum(hi - (as_i32(1) << k) + 1, lo)]
    va = rmq.values[a]
    vb = rmq.values[b]
    # leftmost: prefer a unless b is strictly smaller OR (equal and earlier)
    pick_b = (vb < va) | ((vb == va) & (b < a))
    return jnp.where(pick_b, b, a).astype(IDX)


def rmq_modeled_bits_succinct(n: int) -> int:
    """The paper's choice: Fischer-Heun 2n + o(n) bits."""
    return 2 * n + max(1, n // 4)


def rmq_modeled_bits_table(rmq: SparseTableRMQ) -> int:
    """What our working layout actually stores."""
    return int(rmq.table.size) * 32 + int(rmq.values.size) * 32
