"""Rank/select bitvectors.

Three representations, mirroring the paper's toolbox (Section 2.2 and the
encodings of Section 6.4.1):

* ``PlainBitvector``   — word array + block popcount prefix.  O(1) rank via
  gather+popcount (the Pallas kernel ``repro.kernels.rank`` implements the
  same layout for the TPU hot path), select via searchsorted + in-word scan.
  This plays the role of (Clark 1996) plain bitvectors.

* ``SparseBitvector``  — positions of the 1s (Elias-Fano layout conceptually;
  the working set stores the positions as int32, the *modeled* size is the
  Okanohara-Sadakane bound m lg(n/m) + 2m bits).  rank = binary search,
  select = gather.  Plays the role of "sparse bitmaps" (sd_vector).

* ``RLEBitvector``     — alternating runs.  rank/select via run prefix sums.
  Plays the role of the RLCSA's run-length encoded bitvectors (Sada-RR /
  Sada-RS / Sada-RD in Section 6.4.1); the modeled size uses delta codes.

Conventions (0-based, half-open):
  rank1(bv, i)   = number of 1s in positions [0, i),   0 <= i <= n
  select1(bv, j) = position of the j-th 1 (j in [0, m))

TPU adaptation note: on a scalar CPU these structures answer one query at a
time by pointer chasing; here every query is a pure gather/arith expression,
so a *batch* of queries is a dense vectorized computation (vmap).  This is
the execution-model change recorded in DESIGN.md Section 2.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.common import (
    IDX,
    WORD_BITS,
    as_i32,
    ceil_div,
    delta_code_len,
    elias_fano_bits,
    popcount,
    pytree_dataclass,
)

# ---------------------------------------------------------------------------
# Plain bitvector
# ---------------------------------------------------------------------------


@pytree_dataclass(meta=("n", "m"))
class PlainBitvector:
    """Word-aligned bitvector with popcount prefix blocks.

    words:        uint32[W+1]  (one zero pad word so rank(n) never reads OOB)
    ones_prefix:  int32[W+1]   ones in words [0, w)
    zeros_prefix: int32[W+1]   zeros in positions [0, 32*w) clamped to n
    n:            static length in bits
    m:            static number of ones
    """

    words: jnp.ndarray
    ones_prefix: jnp.ndarray
    zeros_prefix: jnp.ndarray
    n: int
    m: int

    # -- queries ------------------------------------------------------------

    def rank1(self, i):
        """Number of 1s in [0, i).  i may be a traced scalar or array."""
        i = as_i32(i)
        w = i >> 5
        off = i & 31
        word = self.words[w]
        mask = (jnp.uint32(1) << off.astype(jnp.uint32)) - jnp.uint32(1)
        return self.ones_prefix[w] + popcount(word & mask).astype(IDX)

    def rank0(self, i):
        i = as_i32(i)
        return i - self.rank1(i)

    def get(self, i):
        i = as_i32(i)
        return ((self.words[i >> 5] >> (i & 31).astype(jnp.uint32)) & 1).astype(IDX)

    def select1(self, j):
        """Position of the j-th 1 (j in [0, m)).  Out-of-range j returns n."""
        j = as_i32(j)
        # word with ones_prefix[w] <= j < ones_prefix[w+1]
        w = jnp.searchsorted(self.ones_prefix, j, side="right") - 1
        w = jnp.clip(w, 0, self.words.shape[0] - 1).astype(IDX)
        local = j - self.ones_prefix[w]
        word = self.words[w]
        bits = (word >> jnp.arange(WORD_BITS, dtype=jnp.uint32)) & jnp.uint32(1)
        cum = jnp.cumsum(bits.astype(IDX))
        pos_in_word = jnp.argmax(cum == local + 1).astype(IDX)
        ok = (j >= 0) & (j < self.m)
        return jnp.where(ok, w * WORD_BITS + pos_in_word, as_i32(self.n))

    def select0(self, j):
        """Position of the j-th 0 (j in [0, n - m)).  OOR returns n."""
        j = as_i32(j)
        w = jnp.searchsorted(self.zeros_prefix, j, side="right") - 1
        w = jnp.clip(w, 0, self.words.shape[0] - 1).astype(IDX)
        local = j - self.zeros_prefix[w]
        word = self.words[w]
        idx = jnp.arange(WORD_BITS, dtype=IDX)
        bits = ((word >> idx.astype(jnp.uint32)) & jnp.uint32(1)).astype(IDX)
        # positions >= n are padding: they are *not* zeros of the bitvector
        valid = (w * WORD_BITS + idx) < self.n
        zbits = jnp.where(valid, 1 - bits, 0)
        cum = jnp.cumsum(zbits)
        pos_in_word = jnp.argmax(cum == local + 1).astype(IDX)
        ok = (j >= 0) & (j < self.n - self.m)
        return jnp.where(ok, w * WORD_BITS + pos_in_word, as_i32(self.n))

    # -- space accounting ---------------------------------------------------

    def modeled_bits(self) -> int:
        """Paper-model size: n + o(n) (plain bitvector with rank support)."""
        return self.n + ceil_div(self.n, WORD_BITS * 8) * WORD_BITS + 2 * WORD_BITS


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Pack a 0/1 numpy array into uint32 words (little-endian within word)."""
    bits = np.asarray(bits, dtype=np.uint8)
    n = len(bits)
    W = ceil_div(max(n, 1), WORD_BITS)
    padded = np.zeros(W * WORD_BITS, dtype=np.uint8)
    padded[:n] = bits
    lanes = padded.reshape(W, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (lanes << shifts).sum(axis=1, dtype=np.uint32)


def plain_from_bits(bits) -> PlainBitvector:
    """Build from a 0/1 array (host-side; builds are offline, queries are jit)."""
    bits = np.asarray(bits)
    if bits.dtype == np.bool_:
        bits = bits.astype(np.uint8)
    n = int(bits.shape[0])
    words = pack_bits_np(bits)
    pc = np.zeros(len(words) + 1, dtype=np.int32)
    # popcount on host
    pc[1:] = np.cumsum([int(bin(int(w)).count("1")) for w in words], dtype=np.int64)
    m = int(pc[-1])
    ones_prefix = pc
    word_start_pos = np.minimum(np.arange(len(words) + 1, dtype=np.int64) * WORD_BITS, n)
    zeros_prefix = (word_start_pos - pc).astype(np.int32)
    words_padded = np.concatenate([words, np.zeros(1, dtype=np.uint32)])
    # prefix arrays must be indexable at w = W (rank at i == n)
    ones_prefix = np.concatenate([ones_prefix, ones_prefix[-1:]]).astype(np.int32)
    zeros_prefix = np.concatenate([zeros_prefix, zeros_prefix[-1:]]).astype(np.int32)
    return PlainBitvector(
        words=jnp.asarray(words_padded),
        ones_prefix=jnp.asarray(ones_prefix[: len(words_padded)]),
        zeros_prefix=jnp.asarray(zeros_prefix[: len(words_padded)]),
        n=n,
        m=m,
    )


# ---------------------------------------------------------------------------
# Sparse bitvector (Elias-Fano model)
# ---------------------------------------------------------------------------


@pytree_dataclass(meta=("n", "m"))
class SparseBitvector:
    """Positions of ones; rank by binary search, select by gather.

    pos: int32[m]  sorted positions of the 1s  (padded with n if m == 0)
    """

    pos: jnp.ndarray
    n: int
    m: int

    def rank1(self, i):
        i = as_i32(i)
        return jnp.searchsorted(self.pos, i, side="left").astype(IDX)

    def rank0(self, i):
        i = as_i32(i)
        return i - self.rank1(i)

    def get(self, i):
        i = as_i32(i)
        k = jnp.searchsorted(self.pos, i, side="left")
        k = jnp.clip(k, 0, max(self.m - 1, 0))
        hit = (self.m > 0) & (self.pos[k] == i)
        return hit.astype(IDX)

    def select1(self, j):
        j = as_i32(j)
        ok = (j >= 0) & (j < self.m)
        jc = jnp.clip(j, 0, max(self.m - 1, 0))
        return jnp.where(ok, self.pos[jc], as_i32(self.n))

    def select0(self, j):
        """j-th zero: j + (number of ones k with pos[k] - k <= j)."""
        j = as_i32(j)
        shifted = self.pos - jnp.arange(self.m, dtype=IDX)
        t = jnp.searchsorted(shifted, j, side="right").astype(IDX)
        ok = (j >= 0) & (j < self.n - self.m)
        return jnp.where(ok, j + t, as_i32(self.n))

    def modeled_bits(self) -> int:
        return elias_fano_bits(self.m, self.n)


def sparse_from_positions(pos, n: int) -> SparseBitvector:
    pos = np.asarray(pos, dtype=np.int32)
    if pos.size > 1:
        assert (np.diff(pos) > 0).all(), "positions must be strictly increasing"
    if pos.size:
        assert 0 <= pos[0] and pos[-1] < n
    store = pos if pos.size else np.asarray([n], dtype=np.int32)
    return SparseBitvector(pos=jnp.asarray(store), n=int(n), m=int(pos.size))


def sparse_from_bits(bits) -> SparseBitvector:
    bits = np.asarray(bits)
    return sparse_from_positions(np.flatnonzero(bits), int(bits.shape[0]))


# ---------------------------------------------------------------------------
# Run-length encoded bitvector
# ---------------------------------------------------------------------------


@pytree_dataclass(meta=("n", "m", "first_bit", "nruns"))
class RLEBitvector:
    """Alternating runs; run r covers [run_starts[r], run_starts[r+1]).

    run_starts:  int32[R+1]  (last entry == n)
    ones_prefix: int32[R+1]  ones in runs [0, r)
    Value of run r is first_bit ^ (r & 1).
    """

    run_starts: jnp.ndarray
    ones_prefix: jnp.ndarray
    n: int
    m: int
    first_bit: int
    nruns: int

    def _run_of(self, i):
        r = jnp.searchsorted(self.run_starts, i, side="right") - 1
        return jnp.clip(r, 0, self.nruns - 1).astype(IDX)

    def rank1(self, i):
        i = as_i32(i)
        r = self._run_of(jnp.maximum(i - 1, 0))
        r = jnp.where(i <= 0, 0, r)
        # run value = first_bit ^ (r & 1)
        run_val = jnp.bitwise_xor(as_i32(self.first_bit), r & 1)
        within = jnp.where(run_val == 1, i - self.run_starts[r], 0)
        out = self.ones_prefix[r] + within
        return jnp.where(i <= 0, 0, out).astype(IDX)

    def rank0(self, i):
        i = as_i32(i)
        return i - self.rank1(i)

    def get(self, i):
        i = as_i32(i)
        r = self._run_of(i)
        return jnp.bitwise_xor(as_i32(self.first_bit), r & 1)

    def select1(self, j):
        j = as_i32(j)
        r = jnp.searchsorted(self.ones_prefix, j, side="right") - 1
        r = jnp.clip(r, 0, self.nruns - 1).astype(IDX)
        pos = self.run_starts[r] + (j - self.ones_prefix[r])
        ok = (j >= 0) & (j < self.m)
        return jnp.where(ok, pos, as_i32(self.n))

    def select0(self, j):
        j = as_i32(j)
        zeros_prefix = self.run_starts[:-1] - self.ones_prefix[:-1]
        r = jnp.searchsorted(zeros_prefix, j, side="right") - 1
        r = jnp.clip(r, 0, self.nruns - 1).astype(IDX)
        pos = self.run_starts[r] + (j - zeros_prefix[r])
        ok = (j >= 0) & (j < self.n - self.m)
        return jnp.where(ok, pos, as_i32(self.n))

    def modeled_bits(self) -> int:
        """Delta-coded run lengths (the Sada-RR encoding of Section 6.4.1)."""
        starts = np.asarray(self.run_starts)
        lens = np.diff(starts)
        return int(sum(delta_code_len(int(v)) for v in lens if v > 0)) + 2 * 32


def rle_from_bits(bits) -> RLEBitvector:
    bits = np.asarray(bits).astype(np.int8)
    n = int(bits.shape[0])
    if n == 0:
        return RLEBitvector(
            run_starts=jnp.asarray([0], dtype=IDX),
            ones_prefix=jnp.asarray([0], dtype=IDX),
            n=0, m=0, first_bit=0, nruns=1,
        )
    change = np.flatnonzero(np.diff(bits)) + 1
    run_starts = np.concatenate([[0], change, [n]]).astype(np.int64)
    first_bit = int(bits[0])
    nruns = len(run_starts) - 1
    lens = np.diff(run_starts)
    run_vals = np.bitwise_xor(np.arange(nruns) % 2, first_bit)
    ones_per_run = lens * run_vals
    ones_prefix = np.concatenate([[0], np.cumsum(ones_per_run)]).astype(np.int32)
    return RLEBitvector(
        run_starts=jnp.asarray(run_starts.astype(np.int32)),
        ones_prefix=jnp.asarray(ones_prefix),
        n=n,
        m=int(ones_prefix[-1]),
        first_bit=first_bit,
        nruns=nruns,
    )
