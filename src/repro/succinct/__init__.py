"""Succinct data-structure substrate: rank/select bitvectors, wavelet
matrices, and range-minimum queries.

These are the primitives the paper's structures (ILCP, PDL, Sadakane
counting) are built from.  All query paths are jit/vmap-compatible; all
structures are immutable pytrees (see ``repro.common.pytree_dataclass``).
"""

from repro.succinct.bitvector import (
    PlainBitvector,
    RLEBitvector,
    SparseBitvector,
    plain_from_bits,
    rle_from_bits,
    sparse_from_positions,
)
from repro.succinct.rmq import SparseTableRMQ, rmq_build, rmq_query
from repro.succinct.wavelet import (
    WaveletMatrix,
    wm_access,
    wm_build,
    wm_count_less,
    wm_descend,
    wm_rank,
    wm_rank_pair,
    wm_rank_pair_batch,
)

__all__ = [
    "PlainBitvector",
    "SparseBitvector",
    "RLEBitvector",
    "plain_from_bits",
    "sparse_from_positions",
    "rle_from_bits",
    "WaveletMatrix",
    "wm_build",
    "wm_rank",
    "wm_rank_pair",
    "wm_rank_pair_batch",
    "wm_descend",
    "wm_access",
    "wm_count_less",
    "SparseTableRMQ",
    "rmq_build",
    "rmq_query",
]
