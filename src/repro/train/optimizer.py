"""AdamW with ZeRO-friendly state layout.

Moments live in a configurable dtype (f32 default; bf16 for the 400B MoE
where f32 moments would not fit the per-device HBM budget even fully
sharded — recorded in DESIGN.md).  State sharding is decided by
repro.dist.sharding.zero_specs: moments take the parameter's sharding plus
the data axes on the largest still-unsharded divisible dimension (ZeRO-1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: jnp.dtype = jnp.float32
    grad_clip: float | None = 1.0


def adamw_init(params, cfg: AdamWConfig | None = None):
    cfg = cfg if cfg is not None else AdamWConfig()
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params, cfg: AdamWConfig | None = None):
    return jax.eval_shape(lambda: adamw_init(params, cfg))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    if cfg.grad_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + gf * gf * (1 - cfg.b2)
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}
