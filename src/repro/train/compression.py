"""Gradient compression: block-wise int8 quantization with error feedback.

At 1000+ nodes the cross-pod (DCI) all-reduce is the scarce resource; int8
with error feedback cuts gradient bytes 4x vs f32 (2x vs bf16) while the
residual buffer keeps the *accumulated* quantization error in the update
path, preserving convergence (Seide et al. 2014 / EF-SGD, Karimireddy et
al. 2019).

Usage in the hierarchical reduction: reduce-scatter the raw local grads
inside the pod over ICI (cheap), quantize only the cross-pod segment,
all-reduce int8 over DCI, dequantize, all-gather inside the pod.  This
module implements the quantize/dequantize + error-feedback state; the
convergence-parity test trains a small model both ways.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _blockwise_scale(g2d):
    return jnp.max(jnp.abs(g2d), axis=-1, keepdims=True) / 127.0 + 1e-12


def compress_leaf(g, err):
    """Returns (int8 payload, scales, new error feedback)."""
    flat = g.astype(jnp.float32).reshape(-1)
    err = err.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    x = jnp.pad(flat + err, (0, pad)).reshape(-1, BLOCK)
    scale = _blockwise_scale(x)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = (x - deq).reshape(-1)[:n].reshape(g.shape)
    return q, scale, new_err


def decompress_leaf(q, scale, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return deq[:n].reshape(shape)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grads(grads, err_state):
    """Quantize+dequantize every leaf with error feedback.  Returns
    (effective grads as seen post-communication, new error state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_leaf(g, e)
        outs.append(decompress_leaf(q, s, g.shape).astype(g.dtype))
        errs.append(ne)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, errs)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(f32)."""
    total_f32 = sum(l.size * 4 for l in jax.tree.leaves(grads))
    total_c = sum(
        l.size + (l.size + BLOCK - 1) // BLOCK * 4 for l in jax.tree.leaves(grads)
    )
    return total_c / total_f32
