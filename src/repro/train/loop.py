"""Training loop with checkpoint/restart, failure injection and straggler
accounting.

The loop is deliberately framework-grade rather than demo-grade:
  * resume-from-latest on start (crash == restart, no special casing);
  * periodic two-phase checkpoints + pruning;
  * optional FailureInjector that kills the step at a chosen point to
    exercise the recovery path (used by tests);
  * per-step wall-clock telemetry with a straggler detector (steps slower
    than ``straggler_factor`` x median are counted and reported — on a real
    cluster this signal feeds the scheduler's replace/redistribute
    decision, which is simulated in tests by re-meshing);
  * optional int8 error-feedback gradient compression.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np
import jax

from repro.train.checkpoint import (
    latest_checkpoint,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.compression import compressed_grads, init_error_state
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


class FailureInjector:
    """Raises at a specified step (once) to simulate a node failure."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list
    restarts: int
    straggler_steps: int


def train(
    loss_fn: Callable,          # (params, batch) -> scalar loss
    init_params_fn: Callable,   # () -> params
    batch_fn: Callable,         # (step) -> batch
    n_steps: int,
    ckpt_dir: str,
    opt_cfg: AdamWConfig | None = None,
    ckpt_every: int = 20,
    keep_ckpts: int = 3,
    failure: Optional[FailureInjector] = None,
    compress_grads: bool = False,
    straggler_factor: float = 3.0,
    mesh=None,
    param_specs=None,
) -> TrainResult:
    params = init_params_fn()
    opt_cfg = opt_cfg if opt_cfg is not None else AdamWConfig()
    opt_state = adamw_init(params, opt_cfg)
    err_state = init_error_state(params) if compress_grads else None
    start_step = 0
    restarts = 0

    cp = latest_checkpoint(ckpt_dir)
    if cp is not None:
        state = {"params": params, "opt": opt_state}
        restored, start_step = restore_checkpoint(cp[1], state, mesh, param_specs)
        params, opt_state = restored["params"], restored["opt"]
        restarts += 1

    @jax.jit
    def step_fn(params, opt_state, err_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            grads, err_state = compressed_grads(grads, err_state)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, err_state, loss

    losses = []
    durations = []
    straggler_steps = 0
    for step in range(start_step, n_steps):
        if failure is not None:
            failure.maybe_fail(step)
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, err_state, loss = step_fn(
            params, opt_state, err_state, batch
        )
        loss = float(loss)
        dt = time.time() - t0
        durations.append(dt)
        if len(durations) > 8:
            med = float(np.median(durations[-64:]))
            if dt > straggler_factor * med:
                straggler_steps += 1
        losses.append(loss)
        if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
            save_checkpoint(ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            prune_checkpoints(ckpt_dir, keep_ckpts)
    return TrainResult(
        final_step=n_steps,
        losses=losses,
        restarts=restarts,
        straggler_steps=straggler_steps,
    )


def train_with_recovery(*args, max_restarts: int = 3, **kwargs) -> TrainResult:
    """Supervisor: restart on failure, resuming from the latest checkpoint.
    This is the single-process analogue of a cluster controller replacing a
    failed worker and relaunching the job."""
    restarts = 0
    while True:
        try:
            res = train(*args, **kwargs)
            res = dataclasses.replace(res, restarts=res.restarts + restarts)
            return res
        except RuntimeError as e:
            if "injected failure" not in str(e) or restarts >= max_restarts:
                raise
            restarts += 1
