"""Step-atomic checkpointing with crash safety and elastic restore.

Layout:  <root>/step_<N>/  with one .npy per flattened leaf plus a
manifest.json (treedef paths, shapes, dtypes, step).  Writes go to a
``.tmp-`` staging directory first and are renamed into place after fsync —
a checkpoint either exists completely or not at all (two-phase commit).
``COMMITTED`` is written last inside the staged dir; restore ignores any
directory without it, so a process killed mid-save leaves the previous
checkpoint as the restore target.

Elastic scaling: leaves are saved as *global* arrays (gathered); restore
takes a target mesh + partition specs and ``device_put``s each leaf with
its new sharding, so a run checkpointed on N devices resumes on M devices
(the sharding rules in repro.dist.sharding are mesh-shape-agnostic).
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np
import jax

MANIFEST = "manifest.json"
COMMITTED = "COMMITTED"


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save_checkpoint(root: str, step: int, tree) -> str:
    """Two-phase atomic save.  Returns the final directory."""
    final = os.path.join(root, f"step_{step:010d}")
    tmp = os.path.join(root, f".tmp-step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves), "treedef": str(treedef)}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, _leaf_name(i)), arr)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    # commit marker written last; rename is atomic on POSIX
    with open(os.path.join(tmp, COMMITTED), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for name in sorted(os.listdir(root)):
        full = os.path.join(root, name)
        if name.startswith("step_") and os.path.exists(os.path.join(full, COMMITTED)):
            out.append((int(name.split("_")[1]), full))
    return sorted(out)


def latest_checkpoint(root: str):
    cps = list_checkpoints(root)
    return cps[-1] if cps else None


def restore_checkpoint(path: str, like_tree, mesh=None, specs=None):
    """Restore into the structure of ``like_tree``.

    mesh/specs: optional target sharding (elastic restore onto a different
    device count).  Without them, arrays restore as host numpy / default
    placement.
    """
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    restored = []
    spec_leaves = (
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        if specs is not None
        else [None] * len(leaves)
    )
    for i, (ref, spec) in enumerate(zip(leaves, spec_leaves)):
        arr = np.load(os.path.join(path, _leaf_name(i)))
        assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
        if mesh is not None and spec is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec)
            restored.append(jax.device_put(arr.astype(ref.dtype), sharding))
        else:
            restored.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, restored), manifest["step"]


def prune_checkpoints(root: str, keep: int = 3):
    cps = list_checkpoints(root)
    for _, path in cps[:-keep]:
        shutil.rmtree(path)
