"""Training substrate: optimizer, checkpointing, fault tolerance, gradient
compression, and the training loop driver."""
