"""Error taxonomy shared by the core structures and the serving stack.

The split the resilient runtime (repro.serve.runtime) relies on:

* ``InvalidQueryError`` — the *request* is structurally broken (not a
  pattern at all).  Raised at admission time, never from inside a compiled
  program; soft-invalid input (empty pattern, over-long pattern,
  out-of-alphabet symbols) is NOT an error — it normalizes to an empty
  query that flows through the engine and reports empty results.
* ``TransientExecutionError`` — the request was fine but this *attempt*
  failed (device error, injected fault, poisoned payload).  Retryable;
  repeated occurrences trip the circuit breaker and degrade the answer.
* ``DeadlineExceeded`` — the per-request deadline passed; the runtime
  converts this into a degraded (empty) answer rather than raising to the
  caller.
* ``IndexIntegrityError`` — the index pytrees violate a structural
  invariant (repro.serve.validate); the index must be rejected at
  build/load time, never served.
* ``QueueFullError`` — bounded admission queue overflow; the only
  load-shedding signal the runtime surfaces to callers as an exception.
"""

from __future__ import annotations


class ReproError(Exception):
    """Base class for all typed errors raised by this package."""


class InvalidQueryError(ReproError, ValueError):
    """Request is structurally malformed (non-pattern payload, bad dtype,
    bad nesting) — rejected at admission, before any device work."""


class TransientExecutionError(ReproError):
    """A single execution attempt failed; the request itself may be fine.

    The runtime retries these with backoff; attempts exhausted count as a
    circuit-breaker failure and route the request to a degraded path."""


class FaultInjectedError(TransientExecutionError):
    """Raised by repro.serve.faults at an instrumented site."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at {site} (firing #{ordinal})")
        self.site = site
        self.ordinal = ordinal


class PoisonedResultError(TransientExecutionError):
    """An executor returned a payload violating the serving contract
    (sentinels out of range, counts out of bounds) — treated exactly like
    an execution failure so corrupted answers are never served."""


class DeadlineExceeded(ReproError, TimeoutError):
    """The request's deadline passed before a full answer was produced."""


class IndexIntegrityError(ReproError):
    """An index pytree violates a structural invariant and must not serve."""


class QueueFullError(ReproError):
    """Bounded admission queue is full; the request was not admitted."""
