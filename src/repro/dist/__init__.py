"""Distribution layer: mesh-axis policy, sharding rules, roofline accounting.

``sharding`` owns every PartitionSpec decision in the repo (the cell
registry, the optimizer's ZeRO layout, and the checkpoint restore path all
defer to it); ``roofline`` turns compiled-HLO collective traffic plus the
registry's analytic FLOP/byte models into the three roofline terms reported
by the dry-run.
"""

from repro.dist.roofline import CollectiveStats, RooflineTerms, parse_collectives, roofline_terms
from repro.dist.sharding import (
    MeshAxes,
    axes_for_mesh,
    dp_size,
    lm_batch_specs,
    lm_cache_specs,
    lm_param_specs,
    nequip_batch_specs,
    opt_state_specs,
    recsys_param_specs,
    zero_spec_for,
)

__all__ = [
    "CollectiveStats",
    "MeshAxes",
    "RooflineTerms",
    "axes_for_mesh",
    "dp_size",
    "lm_batch_specs",
    "lm_cache_specs",
    "lm_param_specs",
    "nequip_batch_specs",
    "opt_state_specs",
    "parse_collectives",
    "recsys_param_specs",
    "roofline_terms",
    "zero_spec_for",
]
