"""Roofline accounting: HLO collective traffic + analytic FLOP/byte models.

``parse_collectives`` scans compiled HLO text for communication ops and
sizes them from their result shapes.  Under SPMD the printed shapes are
already *per-device* shards, so the byte totals are per-chip wire traffic.
Collectives inside non-entry computations (scan/while bodies) execute once
per trip; the registry passes the trip count via ``scan_trips``.

``roofline_terms`` combines the registry's analytic models with the parsed
traffic into the three classic terms (compute, HBM, interconnect) on TPU
v5e constants, and flags the dominant one.  These populate the dry-run
JSONs consumed by benchmarks.roofline_report and gated by test_registry.
"""

from __future__ import annotations

import dataclasses
import math
import re

# TPU v5e per-chip peaks (order-of-magnitude roofline constants, not
# guarantees): 197 TFLOP/s bf16, 819 GB/s HBM, ~45 GB/s usable ICI per chip.
PEAK_FLOPS = 1.97e14
PEAK_HBM_BPS = 8.19e11
PEAK_ICI_BPS = 4.5e10

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# `%name = f32[8,128]{1,0} all-reduce(...)` — also matches tuple-free starts
_COLL_RE = re.compile(
    r"=\s*\(?([a-z]+\d*)\[([\d,]*)\][^\s]*\s+("
    + "|".join(k.replace("-", r"\-") for k in _COLL_KINDS)
    + r")(?:-start|-done)?\("
)


@dataclasses.dataclass
class CollectiveStats:
    by_kind: dict
    total_bytes: float
    count: int


def _shape_bytes(dtype: str, dims: str) -> float:
    item = _DTYPE_BYTES.get(dtype, 4)
    if not dims:
        return float(item)
    return float(math.prod(int(d) for d in dims.split(",") if d)) * item


def parse_collectives(hlo_text: str, scan_trips: int = 1) -> CollectiveStats:
    by_kind: dict = {}
    count = 0
    in_entry = False
    for line in hlo_text.splitlines():
        # computation headers sit at column 0 and open a brace
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            in_entry = line.startswith("ENTRY")
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        # `-done` halves of async pairs carry the same shape; count starts only
        if "-done(" in line:
            continue
        dtype, dims, kind = m.groups()
        mult = 1 if in_entry else max(1, int(scan_trips))
        # accumulate in Python floats: _shape_bytes is already float, and a
        # numpy 64-bit scalar sneaking in here would widen every report row
        by_kind[kind] = float(by_kind.get(kind, 0.0) + _shape_bytes(dtype, dims) * mult)
        count += mult
    return CollectiveStats(
        by_kind=by_kind, total_bytes=float(sum(by_kind.values())), count=count
    )


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    analytic_flops: float
    useful_ratio: float

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "analytic_flops": self.analytic_flops,
            "useful_ratio": self.useful_ratio,
        }


def roofline_terms(
    meta: dict,
    chips: int,
    collective_bytes: float,
    raw_flops: float = 0.0,
    raw_bytes: float = 0.0,
) -> RooflineTerms:
    """meta: the registry's analytic model (model/analytic flops+bytes).

    raw_flops/raw_bytes come from XLA cost_analysis when available; the
    larger of analytic vs raw is the conservative roofline input (the CPU
    backend's cost analysis undercounts scan bodies, the analytic model can
    miss fusion-added traffic).
    """
    chips = max(1, int(chips))
    model_flops = float(meta.get("model_flops", 0.0))
    # raw_* may arrive as numpy scalars from XLA cost_analysis dicts; pin
    # to Python floats before they mix into the reported terms
    flops = max(float(meta.get("analytic_flops", 0.0)), float(raw_flops))
    bytes_ = max(float(meta.get("analytic_bytes", 0.0)), float(raw_bytes))

    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_ / (chips * PEAK_HBM_BPS)
    # parsed shapes are per-device shards already — no further division
    collective_s = float(collective_bytes) / PEAK_ICI_BPS

    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    useful = model_flops / flops if flops > 0 else 0.0
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        analytic_flops=flops,
        useful_ratio=useful,
    )
