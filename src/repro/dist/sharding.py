"""Sharding rules: every PartitionSpec in the repo is decided here.

Axis policy (see repro.launch.mesh): the last mesh axis is the tensor /
``model`` axis; everything before it is data parallelism (single-pod mesh
``(data, model)``, multi-pod ``(pod, data, model)`` where both leading axes
act as hierarchical DP).  All rules are *divisibility-guarded*: an axis is
used only when the array dimension divides the axis size, so the same rules
are valid on the (1, 1) host mesh, the 16x16 pod, and the 2x16x16 multi-pod
mesh without special cases (GSPMD would pad otherwise — we never rely on
padding for parameters or optimizer state, only activations may).

Rules:

* LM parameters — Megatron-style tensor parallelism over ``model``:
  attention head axes (wq/wk/wv/wo), the FFN hidden dim (w_gate/w_up column,
  w_down row), the MoE expert axis (we_*, matching the shard_map specs in
  repro.models.transformer._moe_ffn_ep), and the vocab dim of embed/lm_head.
  Routers stay replicated (shard_map EP requires it).
* ZeRO (``zero_spec_for``) — add the data axes on the largest
  still-unsharded divisible dimension; applied to optimizer moments always
  (ZeRO-1) and to parameters when the registry enables FSDP.
* KV caches — batch over data, KV-head over model.
* RecSys parameters — large embedding tables row-shard over ``model`` (the
  layout repro.kernels.embedding_bag expects); MLP towers replicate.
"""

from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map across jax versions: the top-level binding (with
    ``check_vma``) landed after 0.4.x; older releases expose it as
    jax.experimental.shard_map.shard_map with the ``check_rep`` spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Role assignment of mesh axes: ``dp`` (tuple, possibly hierarchical),
    ``mdl`` (the tensor-parallel axis), ``all_axes`` in mesh order."""

    dp: tuple
    mdl: str
    all_axes: tuple


def axes_for_mesh(mesh) -> MeshAxes:
    names = tuple(mesh.axis_names)
    if "model" in names:
        mdl = "model"
    else:
        mdl = names[-1]
    dp = tuple(a for a in names if a != mdl)
    if not dp:
        dp = (mdl,)  # degenerate 1-axis mesh: DP == model axis of size 1
    return MeshAxes(dp=dp, mdl=mdl, all_axes=names)


def dp_size(mesh, axes: MeshAxes) -> int:
    return int(math.prod(mesh.shape[a] for a in axes.dp))


def _norm(spec: P, ndim: int) -> list:
    """PartitionSpec entries padded with None to the array rank."""
    entries = list(spec) if spec is not None else []
    return entries + [None] * (ndim - len(entries))


def _axis_if(mesh, axis: str, dim: int) -> str | None:
    return axis if dim % mesh.shape[axis] == 0 else None


# ---------------------------------------------------------------------------
# ZeRO / FSDP extension
# ---------------------------------------------------------------------------


def zero_spec_for(spec: P, shape: tuple, axes: MeshAxes, dpn: int) -> P:
    """Extend ``spec`` with the data axes on the largest still-unsharded
    dimension divisible by the total DP degree.  Returns ``spec`` unchanged
    when nothing qualifies (dpn == 1, fully sharded, or no divisible dim)."""
    if dpn <= 1:
        return spec
    entries = _norm(spec, len(shape))
    used = {
        ax
        for entry in entries
        if entry is not None
        for ax in (entry if isinstance(entry, tuple) else (entry,))
    }
    if used & set(axes.dp):
        return spec  # a dp axis already shards some dim; adding it again
        # elsewhere would be an invalid duplicate-axis PartitionSpec
    best = -1
    for i, (entry, dim) in enumerate(zip(entries, shape)):
        if entry is not None:
            continue
        if dim % dpn != 0:
            continue
        if best < 0 or dim >= shape[best]:
            best = i  # ties resolve to the last (innermost) candidate
    if best < 0:
        return spec
    entries[best] = tuple(axes.dp) if len(axes.dp) > 1 else axes.dp[0]
    return P(*entries)


# ---------------------------------------------------------------------------
# LM specs
# ---------------------------------------------------------------------------

#: blocks/pos* leaf name -> index of the dimension (in the stacked
#: [n_groups, ...] layout) that shards over the model axis; -1 = replicated.
_LM_BLOCK_TP_DIM = {
    "attn_norm": -1,
    "ffn_norm": -1,
    "wq": 2,        # [G, d, H, dh]   heads
    "wk": 2,        # [G, d, K, dh]   kv heads
    "wv": 2,
    "wo": 1,        # [G, H, dh, d]   heads
    "w_gate": 2,    # [G, d, f]       hidden columns
    "w_up": 2,
    "w_down": 1,    # [G, f, d]       hidden rows
    "ws_gate": 2,   # shared expert: same layout as dense FFN
    "ws_up": 2,
    "ws_down": 1,
    "router": -1,   # replicated (shard_map EP contract)
    "we_gate": 1,   # [G, E, d, f]    expert axis (EP over `model`)
    "we_up": 1,
    "we_down": 1,   # [G, E, f, d]
}


def lm_param_specs(cfg, axes: MeshAxes, mesh, params_abs):
    """PartitionSpecs for repro.models.transformer parameter trees."""
    mdl = axes.mdl

    def block_spec(name: str, ab):
        tp_dim = _LM_BLOCK_TP_DIM.get(name, -1)
        entries = [None] * ab.ndim
        if tp_dim >= 0:
            entries[tp_dim] = _axis_if(mesh, mdl, ab.shape[tp_dim])
        return P(*entries)

    specs = {
        "embed": P(_axis_if(mesh, mdl, params_abs["embed"].shape[0]), None),
        "final_norm": P(),
        "blocks": {
            pos: {name: block_spec(name, ab) for name, ab in leaves.items()}
            for pos, leaves in params_abs["blocks"].items()
        },
    }
    if "lm_head" in params_abs:
        specs["lm_head"] = P(
            None, _axis_if(mesh, mdl, params_abs["lm_head"].shape[1])
        )
    return specs


def lm_batch_specs(axes: MeshAxes):
    dp = tuple(axes.dp) if len(axes.dp) > 1 else axes.dp[0]
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_specs(cfg, axes: MeshAxes, batch: int, mesh):
    """Specs matching repro.models.transformer.abstract_cache:
    {pos*: {k, v}} with arrays [n_groups, B, S, n_kv_heads, head_dim]."""
    dpn = dp_size(mesh, axes)
    dp = (tuple(axes.dp) if len(axes.dp) > 1 else axes.dp[0]) if (
        batch % dpn == 0
    ) else None
    kv = _axis_if(mesh, axes.mdl, cfg.n_kv_heads)
    spec = P(None, dp, None, kv, None)
    return {f"pos{p}": {"k": spec, "v": spec} for p in range(cfg.period)}


# ---------------------------------------------------------------------------
# GNN / RecSys specs
# ---------------------------------------------------------------------------


def nequip_batch_specs(axes: MeshAxes, shard: bool = True):
    """Edge/node sharding over *all* axes (GNN batches have no tensor dim)."""
    if not shard:
        return {
            "node_feat": P(), "edge_index": P(), "edge_vec": P(),
            "graph_id": P(), "energy": P(),
        }
    alla = axes.all_axes if len(axes.all_axes) > 1 else axes.all_axes[0]
    return {
        "node_feat": P(alla, None),
        "edge_index": P(None, alla),
        "edge_vec": P(alla, None),
        "graph_id": P(alla),
        "energy": P(),
    }


def recsys_param_specs(params_abs, axes: MeshAxes, mesh, row_threshold: int = 1 << 16):
    """Row-shard large embedding tables over ``model``; replicate the rest.

    The threshold matches the registry's bf16 serving-copy rule: tables with
    >= 2^16 rows are the memory-dominant state and the ones the
    embedding_bag kernel gathers from.
    """

    def spec(ab):
        if ab.ndim == 2 and ab.shape[0] >= row_threshold:
            return P(_axis_if(mesh, axes.mdl, ab.shape[0]), None)
        return P()

    return jax.tree.map(spec, params_abs)


# ---------------------------------------------------------------------------
# Docs-axis sharding (document-retrieval index stack)
# ---------------------------------------------------------------------------

#: mesh axis name the retrieval index stack shards over
DOCS_AXIS = "docs"


def make_docs_mesh(n_shards: int):
    """1-D ``(docs,)`` mesh of ``n_shards`` devices for the sharded index
    stack.  On a CPU host, virtualize devices first with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    imports; tests/conftest.py and the CI sharded-smoke step do this)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    avail = jax.device_count()
    if n_shards > avail:
        raise ValueError(
            f"n_shards={n_shards} exceeds available devices ({avail}); "
            "set --xla_force_host_platform_device_count"
        )
    return jax.make_mesh((n_shards,), (DOCS_AXIS,))


def docs_mesh_size(mesh) -> int:
    return int(mesh.shape[DOCS_AXIS])


def doc_shard_bounds(d: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous document ranges [dlo, dhi) per shard, balanced to within
    one document.  Every shard owns at least one document — build-time
    empty shards are disallowed (an *empty-answer* shard, where a pattern
    has no hits, is the degenerate case the merge handles)."""
    if n_shards > d:
        raise ValueError(
            f"n_shards={n_shards} > d={d}: every shard must own >= 1 document"
        )
    base, extra = divmod(d, n_shards)
    bounds = []
    lo = 0
    for s in range(n_shards):
        hi = lo + base + (1 if s < extra else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def docs_stacked_spec(ndim: int) -> P:
    """Spec for per-shard results stacked on a leading [S, ...] axis: shard
    the leading dim over ``docs``, replicate the rest.  Applied via
    ``jax.lax.with_sharding_constraint`` between the unrolled per-shard
    executors and the shard_map merge stage."""
    return P(DOCS_AXIS, *([None] * (ndim - 1)))


def docs_replicated_spec() -> P:
    """Placement of index pytree leaves and query batches: replicated over
    the docs mesh.  jax.jit rejects mixed single-device placements, so
    per-shard index leaves live replicated; true per-device residency of
    shard s's leaves on device s only is the multi-host follow-up
    (docs/SHARDING.md)."""
    return P()


def docs_index_shardings(mesh, pytree):
    """NamedShardings for device_put of a (per-shard or global) index
    pytree onto the docs mesh — every leaf replicated."""
    sh = jax.NamedSharding(mesh, docs_replicated_spec())
    return jax.tree.map(lambda _: sh, pytree)


# ---------------------------------------------------------------------------
# Optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------


def opt_state_specs(param_specs, params_abs, axes: MeshAxes, dpn: int):
    """Moments: parameter sharding + data axes on the largest free dim
    (ZeRO-1); step counter replicated.  Matches
    repro.train.optimizer.abstract_opt_state's {m, v, step} layout."""
    mspecs = jax.tree.map(
        lambda spec, ab: zero_spec_for(spec, ab.shape, axes, dpn),
        param_specs,
        params_abs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": mspecs, "v": mspecs, "step": P()}
