"""Pallas TPU kernel: batched sparse-table RMQ (leftmost argmin).

The document-listing recursion (Sections 2.3 / 3.3) issues one RMQ per
reported document; a serving batch issues thousands.  Each query is two
VMEM gathers + a compare:

    k = floor(lg(hi - lo + 1))
    a = T[k, lo];  b = T[k, hi - 2^k + 1];  pick leftmost min.

The table rows are flattened so the (k, pos) gather is a single 1-D VMEM
gather (TPU-friendly).  Queries stream through the grid in blocks; the
table/values are VMEM-resident per step (tables for run-head arrays are
rho lg rho words — small on repetitive collections, which is exactly the
paper's regime).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmq_kernel(lo_ref, hi_ref, values_ref, table_ref, out_ref, *, levels, n):
    lo = lo_ref[...]
    hi = hi_ref[...]
    values = values_ref[...]
    table = table_ref[...]  # flattened [levels * n]
    span = jnp.maximum(hi - lo + 1, 1)
    k = 31 - jax.lax.clz(span)
    k = jnp.clip(k, 0, levels - 1)
    right = jnp.maximum(hi - (jnp.int32(1) << k) + 1, lo)
    a = table[k * n + lo]
    b = table[k * n + right]
    va = values[a]
    vb = values[b]
    pick_b = (vb < va) | ((vb == va) & (b < a))
    out_ref[...] = jnp.where(pick_b, b, a).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def rmq_pallas(
    values: jnp.ndarray,
    table: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    *,
    block_q: int = 1024,
    interpret: bool = True,
):
    """Batched leftmost-argmin of values[lo..hi] (inclusive)."""
    levels, n = table.shape
    q = lo.shape[0]
    qpad = -(-q // block_q) * block_q
    lo_p = jnp.zeros(qpad, jnp.int32).at[:q].set(lo)
    hi_p = jnp.zeros(qpad, jnp.int32).at[:q].set(hi)
    flat = table.reshape(-1)
    out = pl.pallas_call(
        functools.partial(_rmq_kernel, levels=levels, n=n),
        grid=(qpad // block_q,),
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec(values.shape, lambda i: (0,)),
            pl.BlockSpec(flat.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((qpad,), jnp.int32),
        interpret=interpret,
    )(lo_p, hi_p, values, flat)
    return out[:q]
