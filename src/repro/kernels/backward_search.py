"""Pallas TPU kernel: fused CSA backward search — one launch per batch.

Every query the serving engine answers starts with the backward search of
the pattern over the BWT wavelet matrix (paper Sections 2.2 / 6.2.2).  The
pre-fusion planner paid for it as ``2 * m * levels`` separate Pallas rank
launches (one per wavelet level per symbol step per range boundary), with
an HBM round-trip for the (lo, hi) carry between every launch.  This kernel
runs the ENTIRE search in one ``pallas_call``:

  * the wavelet matrix's per-level ``words`` / ``ones_prefix`` arrays are
    flattened with a level stride (the RMQ kernel's flattened-sparse-table
    trick) and stay VMEM-resident across the whole search;
  * the query batch streams through the grid in ``block_q`` tiles;
  * inside one grid step, a ``fori_loop`` over the ``max_m`` symbol slots
    wraps a ``fori_loop`` over the levels, carrying the (lo, hi) boundary
    pair so both ranks of a step share one descent;
  * the per-symbol block start of the classic wavelet-matrix rank is
    precomputed at build time (``WaveletMatrix.sym_starts``), folded with
    the C-array into ``base[c] = counts[c] - sym_starts[c]``, so each
    boundary costs ONE rank gather per level.

Patterns arrive right-to-left (processing order) — callers reverse the
padded rows once up front (``repro.kernels.ops.backward_search`` does).
Out-of-alphabet symbols collapse the range to the empty range at the
symbol's lexicographic insertion point (0 below the alphabet, n above),
matching the host binary search's convention; rows padded beyond the true
batch get length 0 and return the untouched (0, n) seed, which callers trim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _backward_search_kernel(
    pat_ref, len_ref, words_ref, prefix_ref, zcount_ref, base_ref,
    lo_ref, hi_ref, *, levels: int, stride: int, n: int, sigma: int,
    max_m: int,
):
    pats = pat_ref[...]          # int32[block_q, max_m], right-to-left
    lengths = len_ref[...]       # int32[block_q]
    words = words_ref[...]       # uint32[levels * stride], VMEM-resident
    prefix = prefix_ref[...]     # int32[levels * stride]
    zcount = zcount_ref[...]     # int32[levels]
    base = base_ref[...]         # int32[sigma]: counts[c] - sym_starts[c]

    def rank1(lvl, pos):
        w = lvl * stride + (pos >> 5)
        off = (pos & 31).astype(jnp.uint32)
        mask = (jnp.uint32(1) << off) - jnp.uint32(1)
        pc = jax.lax.population_count(words[w] & mask).astype(jnp.int32)
        return prefix[w] + pc

    def sym_step(t, carry):
        lo, hi = carry
        c = jax.lax.dynamic_index_in_dim(pats, t, axis=1, keepdims=False)
        active = (t < lengths) & (lo < hi)
        c_ok = (c >= 0) & (c < sigma)
        cc = jnp.clip(c, 0, sigma - 1)

        def level_step(lvl, pq):
            p, q = pq
            bit = (cc >> (levels - 1 - lvl)) & 1
            z = zcount[lvl]
            r1p = rank1(lvl, p)
            r1q = rank1(lvl, q)
            p = jnp.where(bit == 0, p - r1p, z + r1p)
            q = jnp.where(bit == 0, q - r1q, z + r1q)
            return (p, q)

        dlo, dhi = jax.lax.fori_loop(0, levels, level_step, (lo, hi))
        b = base[cc]
        oob = jnp.where(c < 0, 0, n)
        lo = jnp.where(active, jnp.where(c_ok, b + dlo, oob), lo)
        hi = jnp.where(active, jnp.where(c_ok, b + dhi, oob), hi)
        return (lo, hi)

    lo0 = jnp.zeros_like(lengths)
    hi0 = jnp.full_like(lengths, n)
    lo, hi = jax.lax.fori_loop(0, max_m, sym_step, (lo0, hi0))
    lo_ref[...] = lo
    hi_ref[...] = jnp.maximum(lo, hi)


@functools.partial(
    jax.jit, static_argnames=("n", "sigma", "block_q", "interpret")
)
def backward_search_pallas(
    words: jnp.ndarray,        # uint32[levels, W+1] wavelet-matrix words
    ones_prefix: jnp.ndarray,  # int32[levels, W+1]
    zcount: jnp.ndarray,       # int32[levels]
    base: jnp.ndarray,         # int32[sigma]: counts[c] - sym_starts[c]
    rev_patterns: jnp.ndarray, # int32[B, max_m], right-to-left symbol order
    lengths: jnp.ndarray,      # int32[B]
    *,
    n: int,
    sigma: int,
    block_q: int = 256,
    interpret: bool = True,
):
    """Fused batched backward search: (lo int32[B], hi int32[B]).

    ONE ``pallas_call`` regardless of batch size, pattern length, or level
    count — the launch-count contract the serving planner's tests assert.
    """
    levels, stride = words.shape
    B, max_m = rev_patterns.shape
    bq = min(block_q, max(B, 1))
    bpad = -(-B // bq) * bq
    pat_p = jnp.zeros((bpad, max_m), jnp.int32).at[:B].set(rev_patterns)
    len_p = jnp.zeros(bpad, jnp.int32).at[:B].set(lengths)
    kernel = functools.partial(
        _backward_search_kernel,
        levels=levels, stride=stride, n=n, sigma=sigma, max_m=max_m,
    )
    lo, hi = pl.pallas_call(
        kernel,
        grid=(bpad // bq,),
        in_specs=[
            pl.BlockSpec((bq, max_m), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((levels * stride,), lambda i: (0,)),
            pl.BlockSpec((levels * stride,), lambda i: (0,)),
            pl.BlockSpec(zcount.shape, lambda i: (0,)),
            pl.BlockSpec(base.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bpad,), jnp.int32),
            jax.ShapeDtypeStruct((bpad,), jnp.int32),
        ],
        interpret=interpret,
    )(pat_p, len_p, words.reshape(-1), ones_prefix.reshape(-1), zcount, base)
    return lo[:B], hi[:B]
