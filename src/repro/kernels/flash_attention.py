"""Pallas TPU kernel: blocked (flash) attention with online softmax.

Used by the LM-family train/prefill steps.  Grid is (batch, heads,
q_blocks); each step keeps a [block_q, Dh] query tile plus running
(max, denominator, accumulator) in VMEM/registers and streams K/V in
[block_k, Dh] tiles with ``fori_loop`` + dynamic slices, so the S x S score
matrix never materializes.  MXU alignment: block_q/block_k multiples of
128, Dh = 128 for all assigned archs.

Causal semantics support self-attention (S_q == S_kv) and KV-extended
decode/prefill windows (S_kv >= S_q, query i attends to
positions <= S_kv - S_q + i).

GQA is handled above the kernel (repro.models.attention) by reshaping KV
heads; the kernel sees matched Q/KV head counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, scale, s_kv, s_q):
    q = q_ref[0, 0].astype(jnp.float32) * scale        # [BQ, Dh]
    bq = q.shape[0]
    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)      # global query rows
    offset = s_kv - s_q

    nkv = s_kv // block_k

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(j * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(j * block_k, block_k)].astype(jnp.float32)
        logits = q @ k.T                               # [BQ, BK]
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = k_pos[None, :] <= (q_pos[:, None] + offset)
            logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, q.shape[1]), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nkv, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_diff(q, k, v, causal, block_q, block_k, interpret):
    return _flash_forward(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


def _flash_fwd(q, k, v, causal, block_q, block_k, interpret):
    out = _flash_diff(q, k, v, causal, block_q, block_k, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, block_q, block_k, interpret, res, g):
    """Backward pass via recompute against the reference math.

    On TPU the production backward is its own flash kernel (dq/dk/dv tiles
    with the stored log-sum-exp); the recompute VJP keeps training exact
    while the forward takes the Pallas path.
    """
    from repro.kernels import ref

    q, k, v = res
    _, vjp = jax.vjp(
        lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=causal), q, k, v
    )
    return vjp(g)


_flash_diff.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_pallas(
    q, k, v, *, causal=True, block_q=128, block_k=128, interpret=True
):
    """Differentiable entry point: Pallas forward + custom VJP."""
    return _flash_diff(q, k, v, causal, block_q, block_k, interpret)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def _flash_forward(
    q: jnp.ndarray,  # [B, H, Sq, Dh]
    k: jnp.ndarray,  # [B, H, Skv, Dh]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    B, H, Sq, Dh = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    scale = Dh ** -0.5
    grid = (B, H, Sq // block_q)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_k=block_k,
            causal=causal,
            scale=scale,
            s_kv=Skv,
            s_q=Sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, Dh), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Skv, Dh), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
