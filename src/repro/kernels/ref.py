"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rank_ref(words: jnp.ndarray, ones_prefix: jnp.ndarray, idx: jnp.ndarray):
    """Batched rank1: ones in bits [0, idx) of the packed bitvector.

    words: uint32[W(+1)], ones_prefix: int32[W+1], idx: int32[Q].
    """
    w = idx >> 5
    off = (idx & 31).astype(jnp.uint32)
    word = words[w]
    mask = (jnp.uint32(1) << off) - jnp.uint32(1)
    return ones_prefix[w] + jax.lax.population_count(word & mask).astype(jnp.int32)


def rmq_ref(values: jnp.ndarray, table: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray):
    """Batched leftmost-argmin over values[lo..hi] via the sparse table.

    table: int32[Lv, n] (argmin of 2^k windows), lo/hi: int32[Q] inclusive.
    """
    span = jnp.maximum(hi - lo + 1, 1)
    k = 31 - jax.lax.clz(span)
    k = jnp.clip(k, 0, table.shape[0] - 1)
    a = table[k, lo]
    b = table[k, jnp.maximum(hi - (jnp.int32(1) << k) + 1, lo)]
    va = values[a]
    vb = values[b]
    pick_b = (vb < va) | ((vb == va) & (b < a))
    return jnp.where(pick_b, b, a).astype(jnp.int32)


def embedding_bag_ref(
    table: jnp.ndarray, indices: jnp.ndarray, offsets: jnp.ndarray, mode: str = "sum"
):
    """EmbeddingBag: per-bag reduction of gathered rows.

    table: f[V, D]; indices: int32[N]; offsets: int32[B+1] (bag b spans
    indices[offsets[b]:offsets[b+1]]).  Returns f[B, D].
    Implemented with take + segment_sum — the pattern the assignment calls
    out as the system's own responsibility in JAX.
    """
    rows = jnp.take(table, indices, axis=0)
    nbags = offsets.shape[0] - 1
    seg = jnp.repeat(
        jnp.arange(nbags, dtype=jnp.int32),
        offsets[1:] - offsets[:-1],
        total_repeat_length=indices.shape[0],
    )
    summed = jax.ops.segment_sum(rows, seg, num_segments=nbags)
    if mode == "sum":
        return summed
    if mode == "mean":
        counts = (offsets[1:] - offsets[:-1]).astype(summed.dtype)
        return summed / jnp.maximum(counts, 1)[:, None]
    raise ValueError(mode)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True,
    scale: float | None = None,
):
    """Reference attention: q,k,v [B, H, S, Dh] -> [B, H, S, Dh] (f32 math)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, k.shape[2]), dtype=bool), k.shape[2] - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
