"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rank_ref(words: jnp.ndarray, ones_prefix: jnp.ndarray, idx: jnp.ndarray):
    """Batched rank1: ones in bits [0, idx) of the packed bitvector.

    words: uint32[W(+1)], ones_prefix: int32[W+1], idx: int32[Q].
    """
    w = idx >> 5
    off = (idx & 31).astype(jnp.uint32)
    word = words[w]
    mask = (jnp.uint32(1) << off) - jnp.uint32(1)
    return ones_prefix[w] + jax.lax.population_count(word & mask).astype(jnp.int32)


def backward_search_ref(
    words: jnp.ndarray,        # uint32[levels, W+1] wavelet-matrix words
    ones_prefix: jnp.ndarray,  # int32[levels, W+1]
    zcount: jnp.ndarray,       # int32[levels]
    base: jnp.ndarray,         # int32[sigma]: counts[c] - sym_starts[c]
    rev_patterns: jnp.ndarray, # int32[B, max_m], right-to-left symbol order
    lengths: jnp.ndarray,      # int32[B]
    *,
    n: int,
    sigma: int,
):
    """Batched CSA backward search over the BWT wavelet matrix.

    Same operand layout and the same integers as the fused Pallas kernel
    (repro.kernels.backward_search): patterns pre-reversed into processing
    order, both range boundaries sharing one descent per symbol step, one
    rank gather per level per boundary via the precomputed block-start
    ``base``.  Out-of-alphabet symbols collapse to the empty range at the
    symbol's insertion point; length-0 rows return the untouched (0, n).
    """
    levels = words.shape[0]
    B, max_m = rev_patterns.shape
    flat_w = words.reshape(-1)
    flat_p = ones_prefix.reshape(-1)
    stride = words.shape[1]

    def rank1(lvl, pos):
        w = lvl * stride + (pos >> 5)
        off = (pos & 31).astype(jnp.uint32)
        mask = (jnp.uint32(1) << off) - jnp.uint32(1)
        pc = jax.lax.population_count(flat_w[w] & mask).astype(jnp.int32)
        return flat_p[w] + pc

    def sym_step(carry, c):
        lo, hi, t = carry
        active = (t < lengths) & (lo < hi)
        c_ok = (c >= 0) & (c < sigma)
        cc = jnp.clip(c, 0, sigma - 1)

        def level_step(lvl, pq):
            p, q = pq
            bit = (cc >> (levels - 1 - lvl)) & 1
            z = zcount[lvl]
            r1p = rank1(lvl, p)
            r1q = rank1(lvl, q)
            p = jnp.where(bit == 0, p - r1p, z + r1p)
            q = jnp.where(bit == 0, q - r1q, z + r1q)
            return (p, q)

        dlo, dhi = jax.lax.fori_loop(0, levels, level_step, (lo, hi))
        b = base[cc]
        oob = jnp.where(c < 0, 0, n)
        lo = jnp.where(active, jnp.where(c_ok, b + dlo, oob), lo)
        hi = jnp.where(active, jnp.where(c_ok, b + dhi, oob), hi)
        return (lo, hi, t + 1), None

    (lo, hi, _), _ = jax.lax.scan(
        sym_step,
        (jnp.zeros(B, jnp.int32), jnp.full(B, n, jnp.int32), jnp.int32(0)),
        rev_patterns.T,
    )
    return lo, jnp.maximum(lo, hi)


def rmq_ref(values: jnp.ndarray, table: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray):
    """Batched leftmost-argmin over values[lo..hi] via the sparse table.

    table: int32[Lv, n] (argmin of 2^k windows), lo/hi: int32[Q] inclusive.
    """
    span = jnp.maximum(hi - lo + 1, 1)
    k = 31 - jax.lax.clz(span)
    k = jnp.clip(k, 0, table.shape[0] - 1)
    a = table[k, lo]
    b = table[k, jnp.maximum(hi - (jnp.int32(1) << k) + 1, lo)]
    va = values[a]
    vb = values[b]
    pick_b = (vb < va) | ((vb == va) & (b < a))
    return jnp.where(pick_b, b, a).astype(jnp.int32)


def ilcp_list_ref(
    vilcp: jnp.ndarray,       # int32[rho] run head values (RMQ values)
    table: jnp.ndarray,       # int32[levels, rho] sparse-table argmins
    run_starts: jnp.ndarray,  # int32[rho + 1] run boundaries (last = n)
    da: jnp.ndarray,          # int32[n] document array
    lo: jnp.ndarray,          # int32[B] SA-range starts
    hi: jnp.ndarray,          # int32[B] SA-range ends (exclusive)
    lo_run: jnp.ndarray,      # int32[B] run of lo
    hi_run: jnp.ndarray,      # int32[B] run of hi - 1
    *,
    d: int,
    max_df: int,
    rmq_fn=None,
):
    """Batched ILCP document listing over the Fig-1 recursion.

    Same operand layout and the same integers as the fused Pallas kernel
    (repro.kernels.ilcp_list): the per-query recursion is flattened into a
    POP/SCAN state machine and the whole batch advances in lockstep through
    one ``lax.while_loop``, replaying ``ilcp_list_docs`` trajectories
    exactly — documents come out in discovery order, bit-identical to the
    vmap'd while_loop path and to the kernel.

    ``rmq_fn(a, b) -> leftmost argmin of vilcp[a..b]`` may be injected to
    route the popped-interval RMQ through the batched Pallas RMQ kernel
    (``repro.kernels.ops.rmq``); default is the inline two-gather chain.
    """
    from repro.kernels.ilcp_list import (
        lockstep_iteration_cap, pop_cap, stack_cap,
    )

    levels, rho = table.shape
    n = da.shape[0]
    B = lo.shape[0]
    cap = stack_cap(max_df)
    iter_cap = pop_cap(max_df)
    rows = jnp.arange(B, dtype=jnp.int32)
    flat = table.reshape(-1)

    if rmq_fn is None:
        def rmq_fn(a, b):
            span = jnp.maximum(b - a + 1, 1)
            k = jnp.clip(31 - jax.lax.clz(span), 0, levels - 1)
            right = jnp.maximum(b - (jnp.int32(1) << k) + 1, a)
            ia = flat[k * rho + a]
            ib = flat[k * rho + right]
            va = vilcp[ia]
            vb = vilcp[ib]
            pick_b = (vb < va) | ((vb == va) & (ib < ia))
            return jnp.where(pick_b, ib, ia)

    zeros = jnp.zeros(B, jnp.int32)
    init = (
        jnp.int32(0),
        jnp.zeros(B, jnp.bool_),                          # done
        zeros, zeros, zeros, zeros, zeros, zeros,         # mode,a,b,i_run,k,j
        jnp.ones(B, jnp.int32),                           # sp
        zeros, zeros,                                     # cnt, pops
        jnp.zeros((B, cap), jnp.int32).at[:, 0].set(lo_run),
        jnp.zeros((B, cap), jnp.int32).at[:, 0].set(hi_run),
        jnp.zeros((B, d), jnp.bool_),                     # V
        jnp.full((B, max_df), -1, jnp.int32),             # docs
    )

    def cond(c):
        it, done = c[0], c[1]
        return jnp.any(~done) & (it < lockstep_iteration_cap(max_df))

    def body(c):
        (it, done, mode, a, b, i_run, k, j, sp, cnt, pops,
         sa, sb, V, docs) = c

        in_pop = ~done & (mode == 0)
        can_pop = in_pop & (sp > 0) & (cnt < max_df) & (pops < iter_cap)
        done = done | (in_pop & ~can_pop)

        top = jnp.maximum(sp - 1, 0)
        a = jnp.where(can_pop, sa[rows, top], a)
        b = jnp.where(can_pop, sb[rows, top], b)
        sp = jnp.where(can_pop, sp - 1, sp)
        pops = jnp.where(can_pop, pops + 1, pops)

        valid = can_pop & (a <= b) & (lo < hi)
        r = rmq_fn(jnp.clip(a, 0, rho - 1), jnp.clip(b, 0, rho - 1))
        i_run = jnp.where(valid, r, i_run)
        k = jnp.where(
            valid, jnp.maximum(lo, run_starts[jnp.clip(r, 0, rho - 1)]), k
        )
        j = jnp.where(
            valid, jnp.minimum(hi, run_starts[jnp.clip(r + 1, 0, rho)]), j
        )
        mode = jnp.where(valid, 1, mode)

        scanning = ~done & (mode == 1)
        proc = scanning & (k < j) & (cnt < max_df)
        g = da[jnp.clip(k, 0, n - 1)]
        gc = jnp.clip(g, 0, max(d - 1, 0))
        seen = V[rows, gc]
        rep = proc & ~seen
        V = V.at[rows, gc].set(jnp.where(proc, True, seen))
        slot = jnp.minimum(cnt, max_df - 1)
        docs = docs.at[rows, slot].set(jnp.where(rep, g, docs[rows, slot]))
        cnt = jnp.where(rep, cnt + 1, cnt)
        k = jnp.where(proc, k + 1, k)
        aborted = proc & seen
        ended = scanning & (aborted | (k >= j) | (cnt >= max_df))

        push = ended & ~aborted
        slot1 = jnp.minimum(sp, cap - 1)
        do1 = push & (i_run + 1 <= b) & (sp < cap)
        sa = sa.at[rows, slot1].set(jnp.where(do1, i_run + 1, sa[rows, slot1]))
        sb = sb.at[rows, slot1].set(jnp.where(do1, b, sb[rows, slot1]))
        sp = jnp.where(do1, sp + 1, sp)
        slot2 = jnp.minimum(sp, cap - 1)
        do2 = push & (a <= i_run - 1) & (sp < cap)
        sa = sa.at[rows, slot2].set(jnp.where(do2, a, sa[rows, slot2]))
        sb = sb.at[rows, slot2].set(jnp.where(do2, i_run - 1, sb[rows, slot2]))
        sp = jnp.where(do2, sp + 1, sp)
        mode = jnp.where(ended, 0, mode)

        return (it + 1, done, mode, a, b, i_run, k, j, sp, cnt, pops,
                sa, sb, V, docs)

    final = jax.lax.while_loop(cond, body, init)
    return final[14], final[9]


def embedding_bag_ref(
    table: jnp.ndarray, indices: jnp.ndarray, offsets: jnp.ndarray, mode: str = "sum"
):
    """EmbeddingBag: per-bag reduction of gathered rows.

    table: f[V, D]; indices: int32[N]; offsets: int32[B+1] (bag b spans
    indices[offsets[b]:offsets[b+1]]).  Returns f[B, D].
    Implemented with take + segment_sum — the pattern the assignment calls
    out as the system's own responsibility in JAX.
    """
    rows = jnp.take(table, indices, axis=0)
    nbags = offsets.shape[0] - 1
    seg = jnp.repeat(
        jnp.arange(nbags, dtype=jnp.int32),
        offsets[1:] - offsets[:-1],
        total_repeat_length=indices.shape[0],
    )
    summed = jax.ops.segment_sum(rows, seg, num_segments=nbags)
    if mode == "sum":
        return summed
    if mode == "mean":
        counts = (offsets[1:] - offsets[:-1]).astype(summed.dtype)
        return summed / jnp.maximum(counts, 1)[:, None]
    raise ValueError(mode)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True,
    scale: float | None = None,
):
    """Reference attention: q,k,v [B, H, S, Dh] -> [B, H, S, Dh] (f32 math)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, k.shape[2]), dtype=bool), k.shape[2] - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
