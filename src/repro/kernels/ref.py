"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rank_ref(words: jnp.ndarray, ones_prefix: jnp.ndarray, idx: jnp.ndarray):
    """Batched rank1: ones in bits [0, idx) of the packed bitvector.

    words: uint32[W(+1)], ones_prefix: int32[W+1], idx: int32[Q].
    """
    w = idx >> 5
    off = (idx & 31).astype(jnp.uint32)
    word = words[w]
    mask = (jnp.uint32(1) << off) - jnp.uint32(1)
    return ones_prefix[w] + jax.lax.population_count(word & mask).astype(jnp.int32)


def backward_search_ref(
    words: jnp.ndarray,        # uint32[levels, W+1] wavelet-matrix words
    ones_prefix: jnp.ndarray,  # int32[levels, W+1]
    zcount: jnp.ndarray,       # int32[levels]
    base: jnp.ndarray,         # int32[sigma]: counts[c] - sym_starts[c]
    rev_patterns: jnp.ndarray, # int32[B, max_m], right-to-left symbol order
    lengths: jnp.ndarray,      # int32[B]
    *,
    n: int,
    sigma: int,
):
    """Batched CSA backward search over the BWT wavelet matrix.

    Same operand layout and the same integers as the fused Pallas kernel
    (repro.kernels.backward_search): patterns pre-reversed into processing
    order, both range boundaries sharing one descent per symbol step, one
    rank gather per level per boundary via the precomputed block-start
    ``base``.  Out-of-alphabet symbols collapse to the empty range at the
    symbol's insertion point; length-0 rows return the untouched (0, n).
    """
    levels = words.shape[0]
    B, max_m = rev_patterns.shape
    flat_w = words.reshape(-1)
    flat_p = ones_prefix.reshape(-1)
    stride = words.shape[1]

    def rank1(lvl, pos):
        w = lvl * stride + (pos >> 5)
        off = (pos & 31).astype(jnp.uint32)
        mask = (jnp.uint32(1) << off) - jnp.uint32(1)
        pc = jax.lax.population_count(flat_w[w] & mask).astype(jnp.int32)
        return flat_p[w] + pc

    def sym_step(carry, c):
        lo, hi, t = carry
        active = (t < lengths) & (lo < hi)
        c_ok = (c >= 0) & (c < sigma)
        cc = jnp.clip(c, 0, sigma - 1)

        def level_step(lvl, pq):
            p, q = pq
            bit = (cc >> (levels - 1 - lvl)) & 1
            z = zcount[lvl]
            r1p = rank1(lvl, p)
            r1q = rank1(lvl, q)
            p = jnp.where(bit == 0, p - r1p, z + r1p)
            q = jnp.where(bit == 0, q - r1q, z + r1q)
            return (p, q)

        dlo, dhi = jax.lax.fori_loop(0, levels, level_step, (lo, hi))
        b = base[cc]
        oob = jnp.where(c < 0, 0, n)
        lo = jnp.where(active, jnp.where(c_ok, b + dlo, oob), lo)
        hi = jnp.where(active, jnp.where(c_ok, b + dhi, oob), hi)
        return (lo, hi, t + 1), None

    (lo, hi, _), _ = jax.lax.scan(
        sym_step,
        (jnp.zeros(B, jnp.int32), jnp.full(B, n, jnp.int32), jnp.int32(0)),
        rev_patterns.T,
    )
    return lo, jnp.maximum(lo, hi)


def rmq_ref(values: jnp.ndarray, table: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray):
    """Batched leftmost-argmin over values[lo..hi] via the sparse table.

    table: int32[Lv, n] (argmin of 2^k windows), lo/hi: int32[Q] inclusive.
    """
    span = jnp.maximum(hi - lo + 1, 1)
    k = 31 - jax.lax.clz(span)
    k = jnp.clip(k, 0, table.shape[0] - 1)
    a = table[k, lo]
    b = table[k, jnp.maximum(hi - (jnp.int32(1) << k) + 1, lo)]
    va = values[a]
    vb = values[b]
    pick_b = (vb < va) | ((vb == va) & (b < a))
    return jnp.where(pick_b, b, a).astype(jnp.int32)


def embedding_bag_ref(
    table: jnp.ndarray, indices: jnp.ndarray, offsets: jnp.ndarray, mode: str = "sum"
):
    """EmbeddingBag: per-bag reduction of gathered rows.

    table: f[V, D]; indices: int32[N]; offsets: int32[B+1] (bag b spans
    indices[offsets[b]:offsets[b+1]]).  Returns f[B, D].
    Implemented with take + segment_sum — the pattern the assignment calls
    out as the system's own responsibility in JAX.
    """
    rows = jnp.take(table, indices, axis=0)
    nbags = offsets.shape[0] - 1
    seg = jnp.repeat(
        jnp.arange(nbags, dtype=jnp.int32),
        offsets[1:] - offsets[:-1],
        total_repeat_length=indices.shape[0],
    )
    summed = jax.ops.segment_sum(rows, seg, num_segments=nbags)
    if mode == "sum":
        return summed
    if mode == "mean":
        counts = (offsets[1:] - offsets[:-1]).astype(summed.dtype)
        return summed / jnp.maximum(counts, 1)[:, None]
    raise ValueError(mode)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True,
    scale: float | None = None,
):
    """Reference attention: q,k,v [B, H, S, Dh] -> [B, H, S, Dh] (f32 math)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, k.shape[2]), dtype=bool), k.shape[2] - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
