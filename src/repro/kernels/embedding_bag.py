"""Pallas TPU kernel: fused EmbeddingBag (gather + in-register reduce).

The recsys architectures' hot path (DLRM/FM/AutoInt): for each bag, gather
rows of a huge embedding table and reduce.  JAX has no native EmbeddingBag;
the framework's reference path is take + segment_sum (repro.kernels.ref).
This kernel fuses the gather with the bag reduction so gathered rows never
round-trip to HBM: one grid step loads a [block_b, Lmax] index tile, gathers
[block_b, Lmax, D] rows from the VMEM-resident table shard, masks padding,
and writes the [block_b, D] reduced bags.

Layout notes for the production mesh: tables are row-sharded over the
``model`` axis (see repro.dist.sharding); each chip's shard is the
``table`` argument here.  Padded-bag layout (indices [B, Lmax], -1 padding)
matches how Criteo-style multi-hot batches are fed on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _embag_kernel(idx_ref, table_ref, out_ref, *, mode):
    idx = idx_ref[...]                       # [BB, L]
    table = table_ref[...]                   # [V, D]
    safe = jnp.maximum(idx, 0)
    rows = table[safe]                       # [BB, L, D]
    valid = (idx >= 0)[..., None].astype(rows.dtype)
    rows = rows * valid
    summed = rows.sum(axis=1)
    if mode == "mean":
        counts = jnp.maximum(valid.sum(axis=1), 1.0)
        summed = summed / counts
    out_ref[...] = summed.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "block_b", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,       # [V, D]
    padded_idx: jnp.ndarray,  # int32[B, Lmax], -1 = padding
    *,
    mode: str = "sum",
    block_b: int = 128,
    interpret: bool = True,
):
    B, L = padded_idx.shape
    V, D = table.shape
    bpad = -(-B // block_b) * block_b
    idx_p = jnp.full((bpad, L), -1, jnp.int32).at[:B].set(padded_idx)
    out = pl.pallas_call(
        functools.partial(_embag_kernel, mode=mode),
        grid=(bpad // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((V, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, D), table.dtype),
        interpret=interpret,
    )(idx_p, table)
    return out[:B]


def csr_to_padded(indices, offsets, max_len: int):
    """Convert CSR bags (indices, offsets) to the padded [B, Lmax] layout."""
    import numpy as np

    indices = np.asarray(indices)
    offsets = np.asarray(offsets)
    B = len(offsets) - 1
    out = np.full((B, max_len), -1, dtype=np.int32)
    for b in range(B):
        seg = indices[offsets[b] : offsets[b + 1]][:max_len]
        out[b, : len(seg)] = seg
    return out
