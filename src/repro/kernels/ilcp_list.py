"""Pallas TPU kernel: fused ILCP document listing — one launch per batch.

The Fig-1 recursion (paper Section 3.3) is the listing hot path: the
serving executor used to run it as a vmap'd ``lax.while_loop`` issuing one
XLA ``rmq_query`` gather chain per popped interval, with the dedup bitmap
``V`` and result buffer living in HBM between iterations.  This kernel runs
the ENTIRE recursion — bounded explicit stack, leftmost-min sparse-table
RMQ, run→position resolution, document lookup, distinct-doc dedup up to
``max_df`` — inside ONE ``pallas_call`` per padded batch:

  * the flattened RMQ table (the rmq kernel's flattening trick), the run
    head values ``vilcp``, the run boundaries and the document array stay
    VMEM-resident across the whole recursion;
  * the query batch streams through the grid in ``block_q`` tiles;
  * the per-query interval stack and the bit-packed ``V`` marker live in
    VMEM scratch (re-seeded at every grid step — scratch persists across
    grid steps on TPU);
  * the recursion itself is flattened into a per-query POP/SCAN state
    machine so the whole tile advances in lockstep through a single
    ``lax.while_loop``: an iteration either pops an interval and resolves
    its leftmost-min run (POP), or visits one DA position of the current
    run (SCAN).  A query's trajectory — pop order, push filters,
    truncation — replays ``ilcp_list_docs`` exactly, so the reported
    documents are BIT-identical in discovery order, not just as sets.

Callers resolve the query bounds to run indices (``lo_run``/``hi_run``)
up front with one ``searchsorted`` over the run starts — the same
"materialise the access order outside the kernel" move the backward-search
wrapper makes for pattern reversal.  Rows padded past the true batch get
``hi_run = -1``: their root interval is invalid, so they pop once and
retire without touching the tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: stack capacity / pop budget as functions of max_df — shared with the
#: while_loop reference (``ilcp_list_docs``) so trajectories stay aligned.
def stack_cap(max_df: int) -> int:
    return max_df + 4


def pop_cap(max_df: int) -> int:
    return 2 * max_df + 8


def lockstep_iteration_cap(max_df: int) -> int:
    """Safety ceiling on lockstep iterations per tile.  Each pop costs one
    iteration (<= pop_cap) and each visited DA position one more; a visited
    position either reports a new document (<= max_df) or aborts its pop
    (<= pop_cap), so the trajectory of any single query is bounded by
    ``pop_cap + max_df + pop_cap`` iterations plus the final retire step.
    The loop normally exits far earlier on the all-done predicate."""
    return 5 * max_df + 36


_POP = 0
_SCAN = 1


def _ilcp_list_kernel(
    lo_ref, hi_ref, lor_ref, hir_ref, table_ref, vilcp_ref, rs_ref, da_ref,
    docs_ref, cnt_ref, stka_ref, stkb_ref, v_ref, *,
    levels: int, rho: int, n: int, d: int, max_df: int,
):
    lo = lo_ref[...]             # int32[block_q] SA-range starts
    hi = hi_ref[...]             # int32[block_q] SA-range ends (exclusive)
    lo_run = lor_ref[...]        # int32[block_q] run of lo
    hi_run = hir_ref[...]        # int32[block_q] run of hi - 1
    table = table_ref[...]       # int32[levels * rho] flattened RMQ table
    vilcp = vilcp_ref[...]       # int32[rho] run head values
    rs = rs_ref[...]             # int32[rho + 1] run boundaries (last = n)
    da = da_ref[...]             # int32[n] document array

    bq = lo.shape[0]
    rows = jnp.arange(bq, dtype=jnp.int32)
    cap = stack_cap(max_df)
    iter_cap = pop_cap(max_df)
    vw = v_ref.shape[1]

    # scratch is persistent across grid steps: re-seed stack + V every step
    stka_ref[...] = jnp.zeros((bq, cap), jnp.int32).at[:, 0].set(lo_run)
    stkb_ref[...] = jnp.zeros((bq, cap), jnp.int32).at[:, 0].set(hi_run)
    v_ref[...] = jnp.zeros((bq, vw), jnp.uint32)
    docs_ref[...] = jnp.full((bq, max_df), -1, jnp.int32)

    def rmq(a, b):
        # leftmost argmin of vilcp[a..b] — the rmq kernel's flattened gather
        span = jnp.maximum(b - a + 1, 1)
        k = jnp.clip(31 - jax.lax.clz(span), 0, levels - 1)
        right = jnp.maximum(b - (jnp.int32(1) << k) + 1, a)
        ia = table[k * rho + a]
        ib = table[k * rho + right]
        va = vilcp[ia]
        vb = vilcp[ib]
        pick_b = (vb < va) | ((vb == va) & (ib < ia))
        return jnp.where(pick_b, ib, ia)

    def cond(c):
        it, done, *_ = c
        return jnp.any(~done) & (it < lockstep_iteration_cap(max_df))

    def body(c):
        it, done, mode, a, b, i_run, k, j, sp, cnt, pops = c

        # -- POP: take the top interval, resolve its leftmost-min run -------
        in_pop = ~done & (mode == _POP)
        can_pop = in_pop & (sp > 0) & (cnt < max_df) & (pops < iter_cap)
        done = done | (in_pop & ~can_pop)

        sa = stka_ref[...]
        sb = stkb_ref[...]
        top = jnp.maximum(sp - 1, 0)
        a = jnp.where(can_pop, sa[rows, top], a)
        b = jnp.where(can_pop, sb[rows, top], b)
        sp = jnp.where(can_pop, sp - 1, sp)
        pops = jnp.where(can_pop, pops + 1, pops)

        valid = can_pop & (a <= b) & (lo < hi)
        ca = jnp.clip(a, 0, rho - 1)
        r = rmq(ca, jnp.clip(b, 0, rho - 1))
        i_run = jnp.where(valid, r, i_run)
        k = jnp.where(valid, jnp.maximum(lo, rs[jnp.clip(r, 0, rho - 1)]), k)
        j = jnp.where(valid, jnp.minimum(hi, rs[jnp.clip(r + 1, 0, rho)]), j)
        mode = jnp.where(valid, _SCAN, mode)

        # -- SCAN: visit one DA position of the current run -----------------
        # (a freshly popped query scans its first position this iteration)
        scanning = ~done & (mode == _SCAN)
        proc = scanning & (k < j) & (cnt < max_df)
        g = da[jnp.clip(k, 0, n - 1)]
        gc = jnp.clip(g, 0, max(d - 1, 0))
        w = gc >> 5
        bit = jnp.uint32(1) << (gc & 31).astype(jnp.uint32)
        V = v_ref[...]
        vword = V[rows, w]
        seen = (vword & bit) > 0
        rep = proc & ~seen
        v_ref[...] = V.at[rows, w].set(jnp.where(proc, vword | bit, vword))
        docs = docs_ref[...]
        slot = jnp.minimum(cnt, max_df - 1)
        docs_ref[...] = docs.at[rows, slot].set(
            jnp.where(rep, g, docs[rows, slot])
        )
        cnt = jnp.where(rep, cnt + 1, cnt)
        k = jnp.where(proc, k + 1, k)
        aborted = proc & seen
        ended = scanning & (aborted | (k >= j) | (cnt >= max_df))

        # -- push right subrange first, then left (left popped first —
        #    Lemma 3 with the leftmost RMQ); aborts kill the whole subrange
        push = ended & ~aborted
        slot1 = jnp.minimum(sp, cap - 1)
        do1 = push & (i_run + 1 <= b) & (sp < cap)
        sa = sa.at[rows, slot1].set(jnp.where(do1, i_run + 1, sa[rows, slot1]))
        sb = sb.at[rows, slot1].set(jnp.where(do1, b, sb[rows, slot1]))
        sp = jnp.where(do1, sp + 1, sp)
        slot2 = jnp.minimum(sp, cap - 1)
        do2 = push & (a <= i_run - 1) & (sp < cap)
        sa = sa.at[rows, slot2].set(jnp.where(do2, a, sa[rows, slot2]))
        sb = sb.at[rows, slot2].set(jnp.where(do2, i_run - 1, sb[rows, slot2]))
        sp = jnp.where(do2, sp + 1, sp)
        stka_ref[...] = sa
        stkb_ref[...] = sb
        mode = jnp.where(ended, _POP, mode)

        return (it + 1, done, mode, a, b, i_run, k, j, sp, cnt, pops)

    zeros = jnp.zeros(bq, jnp.int32)
    init = (
        jnp.int32(0),                    # lockstep iteration counter
        jnp.zeros(bq, jnp.bool_),        # done
        zeros,                           # mode (all start popping)
        zeros, zeros,                    # (a, b) current interval
        zeros, zeros, zeros,             # i_run, k, j
        jnp.ones(bq, jnp.int32),         # sp (root interval seeded)
        zeros,                           # cnt
        zeros,                           # pops
    )
    final = jax.lax.while_loop(cond, body, init)
    cnt_ref[...] = final[9]


@functools.partial(
    jax.jit, static_argnames=("d", "max_df", "block_q", "interpret")
)
def ilcp_list_pallas(
    vilcp: jnp.ndarray,       # int32[rho] run head values (RMQ values)
    table: jnp.ndarray,       # int32[levels, rho] sparse-table argmins
    run_starts: jnp.ndarray,  # int32[rho + 1] run boundaries (last = n)
    da: jnp.ndarray,          # int32[n] document array
    lo: jnp.ndarray,          # int32[B] SA-range starts
    hi: jnp.ndarray,          # int32[B] SA-range ends (exclusive)
    lo_run: jnp.ndarray,      # int32[B] run of lo
    hi_run: jnp.ndarray,      # int32[B] run of hi - 1
    *,
    d: int,
    max_df: int,
    block_q: int = 128,
    interpret: bool = True,
):
    """Fused batched ILCP listing: (docs int32[B, max_df] padded -1, cnt[B]).

    ONE ``pallas_call`` regardless of batch size, df, or recursion depth —
    the launch-count contract the listing tests assert.  Documents are in
    discovery order, bit-identical to ``ilcp_list_docs_da_batch``.
    """
    levels, rho = table.shape
    n = da.shape[0]
    B = lo.shape[0]
    bq = min(block_q, max(B, 1))
    bpad = -(-B // bq) * bq

    def pad(x, fill):
        return jnp.full(bpad, fill, jnp.int32).at[:B].set(x)

    vw = -(-max(d, 1) // 32)
    kernel = functools.partial(
        _ilcp_list_kernel,
        levels=levels, rho=rho, n=n, d=d, max_df=max_df,
    )
    docs, cnt = pl.pallas_call(
        kernel,
        grid=(bpad // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((levels * rho,), lambda i: (0,)),
            pl.BlockSpec((rho,), lambda i: (0,)),
            pl.BlockSpec(run_starts.shape, lambda i: (0,)),
            pl.BlockSpec(da.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, max_df), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bpad, max_df), jnp.int32),
            jax.ShapeDtypeStruct((bpad,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, stack_cap(max_df)), jnp.int32),   # stack a
            pltpu.VMEM((bq, stack_cap(max_df)), jnp.int32),   # stack b
            pltpu.VMEM((bq, vw), jnp.uint32),                 # V (bit-packed)
        ],
        interpret=interpret,
    )(
        pad(lo, 0), pad(hi, 0), pad(lo_run, 0), pad(hi_run, -1),
        table.reshape(-1), vilcp, run_starts, da,
    )
    return docs[:B], cnt[:B]
