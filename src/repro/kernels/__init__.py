"""Pallas TPU kernels for the framework's compute hot spots.

backward_search — fused CSA backward search: the whole m-step  [paper 2.2/6.2.2]
                  symbol loop x wavelet descent for a pattern
                  batch in ONE pallas_call (launch-count
                  contract: 1 per batch, down from 2*m*levels)
rank            — batched bitvector rank (popcount)            [paper 2.2/5.1]
rmq             — batched sparse-table range-minimum           [paper 2.3/3.3]
embedding_bag   — fused gather+reduce over embedding tables    [recsys archs]
flash_attention — blocked online-softmax attention             [LM archs]

Each kernel ships with a pure-jnp oracle in ref.py; tests sweep shapes and
dtypes against it in interpret mode (this container is CPU-only; TPU is the
compile target).  Wrappers in ops.py auto-detect the backend and fall back
to the oracle on shapes the kernel does not tile.
"""

from repro.kernels.ops import (
    backward_search,
    embedding_bag,
    flash_attention,
    rank,
    rmq,
)

__all__ = ["backward_search", "rank", "rmq", "embedding_bag", "flash_attention"]
