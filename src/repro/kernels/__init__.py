"""Pallas TPU kernels for the framework's compute hot spots.

rank            — batched bitvector rank (popcount)           [paper 2.2/5.1]
rmq             — batched sparse-table range-minimum           [paper 2.3/3.3]
embedding_bag   — fused gather+reduce over embedding tables    [recsys archs]
flash_attention — blocked online-softmax attention             [LM archs]

Each kernel ships with a pure-jnp oracle in ref.py; tests sweep shapes and
dtypes against it in interpret mode (this container is CPU-only; TPU is the
compile target).
"""

from repro.kernels.ops import embedding_bag, flash_attention, rank, rmq

__all__ = ["rank", "rmq", "embedding_bag", "flash_attention"]
