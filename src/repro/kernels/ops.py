"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: Pallas interpret mode on CPU (this
container), compiled Mosaic on TPU.  Every wrapper falls back to the pure
jnp reference when the input shapes don't meet the kernel's tiling
constraints — the framework never fails on odd shapes, it just takes the
XLA path.
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.backward_search import backward_search_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ilcp_list import ilcp_list_pallas, stack_cap
from repro.kernels.rank import rank_pallas
from repro.kernels.rmq import rmq_pallas

#: per-core VMEM the backward-search kernel may claim for the wavelet
#: matrix; larger indexes take the XLA pair-descent path instead (sharding
#: the index over cores is the ROADMAP's per-shard serving follow-up).
BACKWARD_SEARCH_VMEM_BUDGET = 12 * 2**20

#: per-core VMEM the fused listing kernel may claim — resident tables
#: (flattened RMQ table + vilcp + run boundaries + document array) PLUS the
#: per-tile scratch (interval stacks + bit-packed V); past it the executor
#: takes the XLA while_loop path, and sharding restores the kernel exactly
#: as it does for backward search (each shard's tables are ~1/S the size).
ILCP_LIST_VMEM_BUDGET = 12 * 2**20


def backward_search_resident_bytes(words, ones_prefix, zcount, base) -> int:
    """VMEM the fused kernel keeps resident across the whole search: the
    flattened wavelet levels plus the zcount/base tables (every element is
    4 bytes wide — uint32 words, int32 tables).

    Single source of truth for the budget decision: the wrapper below
    compares this against ``BACKWARD_SEARCH_VMEM_BUDGET`` before launching,
    and ``repro.analysis`` re-derives the same number at audit time to
    prove the fallback engages at lowering time."""
    return int(words.size + ones_prefix.size + zcount.size + base.size) * 4


def shards_to_fit(resident_bytes: int,
                  budget: int | None = None) -> int:
    """Smallest docs-mesh shard count that brings a wavelet matrix of
    ``resident_bytes`` under the kernel's VMEM budget, assuming the
    balanced contiguous document split of ``doc_shard_bounds`` (each
    shard's matrix is ~1/S of the whole: same levels, 1/S of the text).

    Sizing hint for ``RetrievalService.build(mesh=...)`` — the serving
    layer restores the fused kernel path for over-budget indexes by
    sharding; see docs/SHARDING.md."""
    if budget is None:
        budget = BACKWARD_SEARCH_VMEM_BUDGET
    if budget <= 0:
        raise ValueError("budget must be positive")
    return max(1, -(-resident_bytes // budget))


def backward_search_block_meta(words, ones_prefix, zcount, base,
                               batch: int, max_m: int, *,
                               block_q: int = 256) -> list:
    """Per-grid-step block layout of the fused kernel as (shape, dtype)
    pairs, mirroring the BlockSpecs in ``backward_search_pallas``.

    Exported for the static VMEM estimator in ``repro.analysis.contracts``:
    summing these blocks bounds what one grid step holds in VMEM, so the
    budget check can run on a traced jaxpr instead of live hardware."""
    levels, stride = words.shape
    bq = min(block_q, max(batch, 1))
    return [
        ((bq, max_m), "int32"),            # pattern tile
        ((bq,), "int32"),                  # lengths tile
        ((levels * stride,), "uint32"),    # flattened words (resident)
        ((levels * stride,), "int32"),     # flattened ones_prefix (resident)
        (tuple(zcount.shape), "int32"),    # zcount (resident)
        (tuple(base.shape), "int32"),      # base = counts - sym_starts
        ((bq,), "int32"),                  # lo out
        ((bq,), "int32"),                  # hi out
    ]


def block_meta_bytes(meta) -> int:
    """Total bytes of a block layout from ``backward_search_block_meta``."""
    return sum(
        int(math.prod(shape)) * np.dtype(dtype).itemsize
        for shape, dtype in meta
    )


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def rank(words, ones_prefix, idx, *, block_q=1024, interpret=None):
    return rank_pallas(
        words, ones_prefix, idx, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def backward_search(words, ones_prefix, zcount, base, patterns, lengths, *,
                    n, sigma, block_q=256, interpret=None):
    """Fused batched CSA backward search (see repro.kernels.backward_search).

    Takes natural left-to-right padded patterns; the right-to-left
    processing order the kernel wants is materialised here with one gather.
    Odd shapes (empty batch, zero-width patterns, degenerate alphabet) and
    wavelet matrices past the VMEM budget fall back to the pure-jnp oracle
    — the framework never fails on shape, it just takes the XLA path.
    """
    patterns = jnp.asarray(patterns, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    B, max_m = patterns.shape
    j = jnp.clip(
        lengths[:, None] - 1 - jnp.arange(max_m, dtype=jnp.int32)[None, :],
        0, max(max_m - 1, 0),
    )
    rev = jnp.take_along_axis(patterns, j, axis=1) if max_m else patterns
    resident_bytes = backward_search_resident_bytes(
        words, ones_prefix, zcount, base
    )
    if (
        B == 0 or max_m == 0 or base.shape[0] == 0
        or resident_bytes > BACKWARD_SEARCH_VMEM_BUDGET
    ):
        return ref.backward_search_ref(
            words, ones_prefix, zcount, base, rev, lengths, n=n, sigma=sigma
        )
    return backward_search_pallas(
        words, ones_prefix, zcount, base, rev, lengths,
        n=n, sigma=sigma, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def rmq(values, table, lo, hi, *, block_q=1024, interpret=None):
    return rmq_pallas(
        values, table, lo, hi, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def ilcp_list_resident_bytes(vilcp, table, run_starts, da) -> int:
    """VMEM the fused listing kernel keeps resident across the recursion:
    the flattened RMQ table, the run head values, the run boundaries and
    the document array (all int32).  Single source of truth for the budget
    decision, like ``backward_search_resident_bytes``."""
    return int(table.size + vilcp.size + run_starts.size + da.size) * 4


def ilcp_list_scratch_bytes(batch: int, *, d: int, max_df: int,
                            block_q: int = 128) -> int:
    """VMEM scratch one grid step of the listing kernel allocates: two
    int32 interval stacks of ``stack_cap(max_df)`` entries per query plus
    the bit-packed distinct-document marker (ceil(d/32) uint32 words)."""
    bq = min(block_q, max(batch, 1))
    vw = -(-max(d, 1) // 32)
    return (2 * bq * stack_cap(max_df) + bq * vw) * 4


def ilcp_list_block_meta(vilcp, table, run_starts, da,
                         batch: int, *, d: int, max_df: int,
                         block_q: int = 128) -> list:
    """Per-grid-step block layout of the fused listing kernel as
    (shape, dtype) pairs, mirroring the BlockSpecs AND the
    ``scratch_shapes`` in ``ilcp_list_pallas`` — the scratch entries are
    what forced the analysis estimator to learn about scratch operands.
    Summing via ``block_meta_bytes`` bounds one grid step's VMEM."""
    levels, rho = table.shape
    bq = min(block_q, max(batch, 1))
    vw = -(-max(d, 1) // 32)
    return [
        ((bq,), "int32"),                  # lo tile
        ((bq,), "int32"),                  # hi tile
        ((bq,), "int32"),                  # lo_run tile
        ((bq,), "int32"),                  # hi_run tile
        ((levels * rho,), "int32"),        # flattened RMQ table (resident)
        ((rho,), "int32"),                 # vilcp (resident)
        (tuple(run_starts.shape), "int32"),  # run boundaries (resident)
        (tuple(da.shape), "int32"),        # document array (resident)
        ((bq, max_df), "int32"),           # docs out
        ((bq,), "int32"),                  # cnt out
        ((bq, stack_cap(max_df)), "int32"),  # scratch: stack a
        ((bq, stack_cap(max_df)), "int32"),  # scratch: stack b
        ((bq, vw), "uint32"),              # scratch: bit-packed V
    ]


def runs_of(run_starts, pos):
    """Run index containing ILCP position ``pos`` (vectorised ``_run_of``:
    rank1 over the run-start bitvector = searchsorted over the starts).
    ``pos = -1`` (empty range roots) maps to run -1."""
    starts = run_starts[: run_starts.shape[0] - 1]
    return (
        jnp.searchsorted(starts, jnp.asarray(pos, jnp.int32), side="right")
        .astype(jnp.int32) - 1
    )


def ilcp_list(vilcp, table, run_starts, da, lo, hi, *,
              d, max_df, block_q=128, interpret=None):
    """Fused batched ILCP document listing (see repro.kernels.ilcp_list).

    Takes SA ranges; the run indices of the range endpoints the kernel
    wants are materialised here with one searchsorted per boundary — the
    backward-search wrapper's pattern-reversal move.  Odd shapes (empty
    batch, zero ``max_df``) and index stacks past the VMEM budget fall
    back to the pure-jnp lockstep oracle — the framework never fails on
    shape, it just takes the XLA path.
    """
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    B = lo.shape[0]
    if B == 0 or max_df <= 0 or d <= 0:
        # degenerate shapes have a closed-form answer (no documents); the
        # (B, 0) docs buffer can't even be scatter-indexed by the oracle
        return (jnp.full((B, max(max_df, 0)), -1, jnp.int32),
                jnp.zeros((B,), jnp.int32))
    lo_run = runs_of(run_starts, lo)
    hi_run = runs_of(run_starts, hi - 1)
    vmem_bytes = block_meta_bytes(ilcp_list_block_meta(
        vilcp, table, run_starts, da, B, d=d, max_df=max_df, block_q=block_q
    ))
    if vmem_bytes > ILCP_LIST_VMEM_BUDGET:
        return ref.ilcp_list_ref(
            vilcp, table, run_starts, da, lo, hi, lo_run, hi_run,
            d=d, max_df=max_df,
        )
    return ilcp_list_pallas(
        vilcp, table, run_starts, da, lo, hi, lo_run, hi_run,
        d=d, max_df=max_df, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def embedding_bag(table, padded_idx, *, mode="sum", block_b=128, interpret=None):
    return embedding_bag_pallas(
        table, padded_idx, mode=mode, block_b=block_b,
        interpret=_auto_interpret(interpret),
    )


def flash_attention(
    q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None
):
    Sq, Skv = q.shape[2], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk,
        interpret=_auto_interpret(interpret),
    )
