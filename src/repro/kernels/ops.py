"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: Pallas interpret mode on CPU (this
container), compiled Mosaic on TPU.  Every wrapper falls back to the pure
jnp reference when the input shapes don't meet the kernel's tiling
constraints — the framework never fails on odd shapes, it just takes the
XLA path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rank import rank_pallas
from repro.kernels.rmq import rmq_pallas


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def rank(words, ones_prefix, idx, *, block_q=1024, interpret=None):
    return rank_pallas(
        words, ones_prefix, idx, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def rmq(values, table, lo, hi, *, block_q=1024, interpret=None):
    return rmq_pallas(
        values, table, lo, hi, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def embedding_bag(table, padded_idx, *, mode="sum", block_b=128, interpret=None):
    return embedding_bag_pallas(
        table, padded_idx, mode=mode, block_b=block_b,
        interpret=_auto_interpret(interpret),
    )


def flash_attention(
    q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None
):
    Sq, Skv = q.shape[2], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk,
        interpret=_auto_interpret(interpret),
    )
