"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: Pallas interpret mode on CPU (this
container), compiled Mosaic on TPU.  Every wrapper falls back to the pure
jnp reference when the input shapes don't meet the kernel's tiling
constraints — the framework never fails on odd shapes, it just takes the
XLA path.
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.backward_search import backward_search_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rank import rank_pallas
from repro.kernels.rmq import rmq_pallas

#: per-core VMEM the backward-search kernel may claim for the wavelet
#: matrix; larger indexes take the XLA pair-descent path instead (sharding
#: the index over cores is the ROADMAP's per-shard serving follow-up).
BACKWARD_SEARCH_VMEM_BUDGET = 12 * 2**20


def backward_search_resident_bytes(words, ones_prefix, zcount, base) -> int:
    """VMEM the fused kernel keeps resident across the whole search: the
    flattened wavelet levels plus the zcount/base tables (every element is
    4 bytes wide — uint32 words, int32 tables).

    Single source of truth for the budget decision: the wrapper below
    compares this against ``BACKWARD_SEARCH_VMEM_BUDGET`` before launching,
    and ``repro.analysis`` re-derives the same number at audit time to
    prove the fallback engages at lowering time."""
    return int(words.size + ones_prefix.size + zcount.size + base.size) * 4


def shards_to_fit(resident_bytes: int,
                  budget: int | None = None) -> int:
    """Smallest docs-mesh shard count that brings a wavelet matrix of
    ``resident_bytes`` under the kernel's VMEM budget, assuming the
    balanced contiguous document split of ``doc_shard_bounds`` (each
    shard's matrix is ~1/S of the whole: same levels, 1/S of the text).

    Sizing hint for ``RetrievalService.build(mesh=...)`` — the serving
    layer restores the fused kernel path for over-budget indexes by
    sharding; see docs/SHARDING.md."""
    if budget is None:
        budget = BACKWARD_SEARCH_VMEM_BUDGET
    if budget <= 0:
        raise ValueError("budget must be positive")
    return max(1, -(-resident_bytes // budget))


def backward_search_block_meta(words, ones_prefix, zcount, base,
                               batch: int, max_m: int, *,
                               block_q: int = 256) -> list:
    """Per-grid-step block layout of the fused kernel as (shape, dtype)
    pairs, mirroring the BlockSpecs in ``backward_search_pallas``.

    Exported for the static VMEM estimator in ``repro.analysis.contracts``:
    summing these blocks bounds what one grid step holds in VMEM, so the
    budget check can run on a traced jaxpr instead of live hardware."""
    levels, stride = words.shape
    bq = min(block_q, max(batch, 1))
    return [
        ((bq, max_m), "int32"),            # pattern tile
        ((bq,), "int32"),                  # lengths tile
        ((levels * stride,), "uint32"),    # flattened words (resident)
        ((levels * stride,), "int32"),     # flattened ones_prefix (resident)
        (tuple(zcount.shape), "int32"),    # zcount (resident)
        (tuple(base.shape), "int32"),      # base = counts - sym_starts
        ((bq,), "int32"),                  # lo out
        ((bq,), "int32"),                  # hi out
    ]


def block_meta_bytes(meta) -> int:
    """Total bytes of a block layout from ``backward_search_block_meta``."""
    return sum(
        int(math.prod(shape)) * np.dtype(dtype).itemsize
        for shape, dtype in meta
    )


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def rank(words, ones_prefix, idx, *, block_q=1024, interpret=None):
    return rank_pallas(
        words, ones_prefix, idx, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def backward_search(words, ones_prefix, zcount, base, patterns, lengths, *,
                    n, sigma, block_q=256, interpret=None):
    """Fused batched CSA backward search (see repro.kernels.backward_search).

    Takes natural left-to-right padded patterns; the right-to-left
    processing order the kernel wants is materialised here with one gather.
    Odd shapes (empty batch, zero-width patterns, degenerate alphabet) and
    wavelet matrices past the VMEM budget fall back to the pure-jnp oracle
    — the framework never fails on shape, it just takes the XLA path.
    """
    patterns = jnp.asarray(patterns, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    B, max_m = patterns.shape
    j = jnp.clip(
        lengths[:, None] - 1 - jnp.arange(max_m, dtype=jnp.int32)[None, :],
        0, max(max_m - 1, 0),
    )
    rev = jnp.take_along_axis(patterns, j, axis=1) if max_m else patterns
    resident_bytes = backward_search_resident_bytes(
        words, ones_prefix, zcount, base
    )
    if (
        B == 0 or max_m == 0 or base.shape[0] == 0
        or resident_bytes > BACKWARD_SEARCH_VMEM_BUDGET
    ):
        return ref.backward_search_ref(
            words, ones_prefix, zcount, base, rev, lengths, n=n, sigma=sigma
        )
    return backward_search_pallas(
        words, ones_prefix, zcount, base, rev, lengths,
        n=n, sigma=sigma, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def rmq(values, table, lo, hi, *, block_q=1024, interpret=None):
    return rmq_pallas(
        values, table, lo, hi, block_q=block_q,
        interpret=_auto_interpret(interpret),
    )


def embedding_bag(table, padded_idx, *, mode="sum", block_b=128, interpret=None):
    return embedding_bag_pallas(
        table, padded_idx, mode=mode, block_b=block_b,
        interpret=_auto_interpret(interpret),
    )


def flash_attention(
    q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None
):
    Sq, Skv = q.shape[2], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk,
        interpret=_auto_interpret(interpret),
    )
