"""Pallas TPU kernel: batched bitvector rank.

rank1(i) = ones_prefix[i >> 5] + popcount(words[i >> 5] & ((1 << (i & 31)) - 1))

This is the innermost primitive of every succinct structure in the paper
(Sections 2.2, 3.3, 5.1): document-array access (rank over B), run mapping
(rank over L), and the H' counting queries are all rank calls.  On TPU the
bitvector words and the block popcount prefix are VMEM-resident (a 100 MB
collection has a 12.5 MB bitvector — fits v5e VMEM budget when sharded per
core; larger vectors tile the query stream instead), queries stream through
the grid in blocks, and popcount is a native VPU op.

Layout: one grid step processes ``block_q`` queries; the words/prefix arrays
are broadcast to every step (index_map -> block 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(idx_ref, words_ref, prefix_ref, out_ref):
    idx = idx_ref[...]
    w = idx >> 5
    off = (idx & 31).astype(jnp.uint32)
    words = words_ref[...]
    prefix = prefix_ref[...]
    word = words[w]
    mask = (jnp.uint32(1) << off) - jnp.uint32(1)
    pc = jax.lax.population_count(word & mask).astype(jnp.int32)
    out_ref[...] = prefix[w] + pc


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def rank_pallas(
    words: jnp.ndarray,
    ones_prefix: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    block_q: int = 1024,
    interpret: bool = True,
):
    """Batched rank1 queries.  idx int32[Q] (multiple of block_q after
    padding, handled here)."""
    q = idx.shape[0]
    qpad = -(-q // block_q) * block_q
    idx_p = jnp.zeros(qpad, jnp.int32).at[:q].set(idx)
    grid = (qpad // block_q,)
    out = pl.pallas_call(
        _rank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec(words.shape, lambda i: (0,)),
            pl.BlockSpec(ones_prefix.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((qpad,), jnp.int32),
        interpret=interpret,
    )(idx_p, words, ones_prefix)
    return out[:q]
