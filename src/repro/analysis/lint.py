"""Repo-specific AST lint — the invariants ruff can't express.

Four rules, each born from a contract an earlier PR established by
convention and that only grep enforced until now:

* **RT001** — no direct ``time.time()`` / ``time.sleep()`` /
  ``time.monotonic()`` / ``time.perf_counter()`` calls under
  ``repro/serve/``.  The runtime's determinism story (deadline tests,
  breaker cooldowns, fault schedules) rests on every clock read going
  through the injectable ``clock=`` / ``sleep=`` parameters; one direct
  call makes a codepath untestable.  *References* (``clock=time.monotonic``
  as a default) are exactly the injection pattern and stay legal.
* **TR001** — no host sync or Python branching on traced values inside
  ``*_batch`` executors and ``repro/kernels/``: ``.item()``, ``float(x)`` /
  ``int(x)`` / ``bool(x)`` on a positional parameter, or ``if`` / ``while``
  / ternary tests reading one.  Positional-no-default parameters of these
  functions are traced arrays by the serving ABI; branching on one either
  crashes under jit or silently forces a device sync per batch.  Static
  knobs ride keyword-only / defaulted parameters, which the rule ignores
  (``.shape`` / ``.ndim`` / ``.size`` / ``.dtype`` reads are static too).
* **FJ001** — fault sites are introduced only through the
  ``repro.serve.faults`` hooks (``faults.fire`` / ``faults.poison``), only
  in the instrumented serving module, and never inside a ``*reference*``
  function: the reference path is the degradation ladder's last resort and
  must stay fault-free.  Raising ``FaultInjectedError`` directly anywhere
  outside ``repro.serve.faults`` counts as an unregistered fault site.
* **JX001** — no jit execution at module import time: calling a
  ``jax.jit``-wrapped callable (or ``jax.jit(f)(...)`` immediately) at
  module scope traces and compiles during import, which breaks
  ``JAX_PLATFORMS``-less tooling, slows every CLI, and hides compile cost
  from the serving metrics.  *Wrapping* at module scope (decorators,
  ``g = jax.jit(f)``) is the normal idiom and stays legal.

Violations may be suppressed by ``allowlist.json`` next to this module —
a comment-free JSON map of rule id to ``path`` or ``path:qualname``
entries; keep it narrow.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib

_ALLOWLIST_FILE = pathlib.Path(__file__).with_name("allowlist.json")

_TIME_CALLS = {"time", "sleep", "monotonic", "perf_counter"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}
_CAST_BUILTINS = {"float", "int", "bool"}
_FAULT_HOOKS = {"fire", "poison"}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str                # repo-relative posix path
    line: int
    qualname: str            # enclosing function ("<module>" at top level)
    message: str
    fixit: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"


FIXITS = {
    "RT001": (
        "take the clock as an injectable parameter (clock=time.monotonic / "
        "sleep=time.sleep defaults, as ServeRuntime does) and call that"
    ),
    "TR001": (
        "keep the branch on-device: jnp.where / lax.cond / lax.select on "
        "the traced value, or move the static knob to a keyword-only "
        "parameter so the tracer never sees it"
    ),
    "FJ001": (
        "instrument the site with faults.fire()/faults.poison() from "
        "repro.serve.faults inside the batched serving path only — the "
        "reference path must stay the fault-free degradation target"
    ),
    "JX001": (
        "wrap at module scope but call lazily: move the call into a "
        "function, or route compilation through the serving layer's AOT "
        "compile cache so the cost is metered"
    ),
}


def _load_allowlist(path: pathlib.Path = _ALLOWLIST_FILE) -> dict:
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def _is_jit_wrap(node: ast.AST) -> bool:
    """True for ``jax.jit(...)`` / ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == "jit" and \
            isinstance(f.value, ast.Name) and f.value.id == "jax":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "partial" or \
            isinstance(f, ast.Name) and f.id == "partial":
        return any(_is_jit_name(a) for a in node.args)
    return False


def _is_jit_name(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "jit" and \
        isinstance(node.value, ast.Name) and node.value.id == "jax"


class _FileLinter(ast.NodeVisitor):
    """One pass over one file; rules share the qualname/scope bookkeeping."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.out: list[LintViolation] = []
        self._scope: list[str] = []
        self._func_depth = 0
        self._jitted_names: set[str] = set()
        self.in_serve = "serve/" in path
        self.in_kernels = "kernels/" in path
        self.is_faults_mod = path.endswith("serve/faults.py")

    # -- bookkeeping ---------------------------------------------------------

    @property
    def qualname(self) -> str:
        return ".".join(self._scope) if self._scope else "<module>"

    def flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.out.append(LintViolation(
            rule=rule, path=self.path, line=node.lineno,
            qualname=self.qualname, message=message, fixit=FIXITS[rule],
        ))

    # -- module-level jit execution (JX001) ----------------------------------

    def _scan_module_jit(self) -> None:
        for node in self.tree.body:
            self._collect_jit_bindings(node)
        for stmt in self.tree.body:
            self._check_module_calls(stmt)

    def _collect_jit_bindings(self, node: ast.stmt) -> None:
        if isinstance(node, ast.Assign) and _is_jit_wrap(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self._jitted_names.add(tgt.id)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_wrap(d) or _is_jit_name(d)
                   for d in node.decorator_list):
                self._jitted_names.add(node.name)

    def _check_module_calls(self, stmt: ast.stmt) -> None:
        # descend into module-level control flow, but not into defs/classes
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id in self._jitted_names:
                self.flag("JX001", node, (
                    f"jit-compiled {f.id!r} executed at module import time"
                ))
            elif isinstance(f, ast.Call) and _is_jit_wrap(f):
                self.flag("JX001", node, (
                    "jax.jit(...)(...) executed at module import time"
                ))

    # -- scoped rules --------------------------------------------------------

    def visit_FunctionDef(self, node):
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node):
        self._visit_func(node)

    def visit_ClassDef(self, node):
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def _visit_func(self, node) -> None:
        self._scope.append(node.name)
        self._func_depth += 1
        if self.in_kernels or node.name.endswith("_batch"):
            self._check_traced_scope(node)
        if "reference" in node.name:
            self._check_reference_path(node)
        self.generic_visit(node)
        self._func_depth -= 1
        self._scope.pop()

    def visit_Call(self, node):
        # RT001: direct wall-clock calls in the serving layer
        f = node.func
        if self.in_serve and isinstance(f, ast.Attribute) and \
                f.attr in _TIME_CALLS and isinstance(f.value, ast.Name) and \
                f.value.id == "time":
            self.flag("RT001", node, (
                f"direct time.{f.attr}() call in repro/serve/ — the runtime "
                f"clock must be injectable"
            ))
        # FJ001: fault hooks outside the instrumented serving modules
        # (the single-device engine and its docs-mesh sharded counterpart —
        # the fault-injection smoke exercises both)
        if self._is_fault_hook(node) and not self.is_faults_mod and \
                not self.path.endswith(("serve/retrieval.py",
                                        "serve/sharded.py")):
            self.flag("FJ001", node, (
                "fault site introduced outside the instrumented serving "
                "modules (repro/serve/{retrieval,sharded}.py)"
            ))
        if isinstance(f, ast.Name) and f.id == "FaultInjectedError" and \
                not self.is_faults_mod:
            self.flag("FJ001", node, (
                "FaultInjectedError raised directly — unregistered fault "
                "site bypassing the seeded schedules"
            ))
        self.generic_visit(node)

    @staticmethod
    def _is_fault_hook(node: ast.Call) -> bool:
        f = node.func
        return isinstance(f, ast.Attribute) and f.attr in _FAULT_HOOKS and \
            isinstance(f.value, ast.Name) and f.value.id == "faults"

    # -- TR001 helpers -------------------------------------------------------

    @staticmethod
    def _traced_params(node) -> set:
        """Positional-no-default parameter names: traced arrays by the
        serving ABI (static knobs are keyword-only or defaulted)."""
        args = node.args
        pos = list(args.posonlyargs) + list(args.args)
        n_default = len(args.defaults)
        traced = pos[: len(pos) - n_default] if n_default else pos
        return {a.arg for a in traced if a.arg not in ("self", "cls")}

    def _static_names(self, expr: ast.AST) -> set:
        """Names only reached through static attributes (x.shape, x.ndim)
        inside ``expr`` — reading those is not a host sync."""
        static = set()
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS \
                    and isinstance(sub.value, ast.Name):
                static.add(sub.value.id)
        return static

    def _check_traced_scope(self, node) -> None:
        traced = self._traced_params(node)
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                    sub is not node:
                # nested helpers' parameters shadow the outer traced names
                traced = traced - self._traced_params(sub)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                f = sub.func
                if isinstance(f, ast.Attribute) and f.attr == "item":
                    self.flag("TR001", sub, (
                        ".item() host sync inside a batched/kernel scope"
                    ))
                elif isinstance(f, ast.Name) and f.id in _CAST_BUILTINS and \
                        sub.args and isinstance(sub.args[0], ast.Name) and \
                        sub.args[0].id in traced:
                    self.flag("TR001", sub, (
                        f"{f.id}({sub.args[0].id}) forces a host sync on a "
                        f"traced parameter"
                    ))
            tests = []
            if isinstance(sub, (ast.If, ast.While)):
                tests.append(sub.test)
            elif isinstance(sub, ast.IfExp):
                tests.append(sub.test)
            for test in tests:
                static_ok = self._static_names(test)
                for name in ast.walk(test):
                    if isinstance(name, ast.Name) and name.id in traced and \
                            name.id not in static_ok and \
                            isinstance(name.ctx, ast.Load):
                        self.flag("TR001", test, (
                            f"Python branch on traced parameter "
                            f"{name.id!r} inside a batched/kernel scope"
                        ))
                        break

    # -- FJ001: reference path must stay uninstrumented ----------------------

    def _check_reference_path(self, node) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and self._is_fault_hook(sub):
                self.flag("FJ001", sub, (
                    f"fault site inside reference-path function "
                    f"{node.name!r} — the degradation target must stay "
                    f"fault-free"
                ))


def lint_file(path: pathlib.Path, rel: str) -> list[LintViolation]:
    tree = ast.parse(path.read_text(), filename=str(path))
    linter = _FileLinter(rel, tree)
    linter._scan_module_jit()
    linter.visit(tree)
    return linter.out


def _allowed(v: LintViolation, allowlist: dict) -> bool:
    entries = allowlist.get(v.rule, [])
    return v.path in entries or f"{v.path}:{v.qualname}" in entries


def lint_tree(root, allowlist: dict | None = None) -> tuple[list, dict]:
    """Lint every .py file under ``root``.  Returns (violations, stats)."""
    root = pathlib.Path(root)
    allowlist = _load_allowlist() if allowlist is None else allowlist
    violations, files = [], 0
    for path in sorted(root.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        files += 1
        rel = path.relative_to(root).as_posix()
        for v in lint_file(path, rel):
            if not _allowed(v, allowlist):
                violations.append(v)
    stats = {
        "files_scanned": files,
        "rules": sorted(FIXITS),
        "allowlisted": {r: len(v) for r, v in (allowlist or {}).items()},
    }
    return violations, stats
