"""Declarative endpoint contracts + the jaxpr auditor that enforces them.

Every compiled serving endpoint (kind x pow2 batch bucket x backend) from
``repro.serve.retrieval`` carries implicit invariants that, until this
module, were enforced by two hand-rolled assertions in tests and nothing
else:

* **launch count** — the fused backward-search path lowers to exactly ONE
  ``pallas_call`` per batch; the XLA pair-descent fallback lowers to ZERO.
  A second launch (or a lost one) is a silent 2x regression that no
  correctness test notices.  The ``list`` endpoint's kernel path adds the
  fused ILCP listing launch on top of the search launch: exactly TWO
  per program (``2 * S`` sharded — each shard launches its own pair),
  and still ZERO on the XLA / over-budget fallback.
* **gather ceiling** — the pair-descent range search issues a bounded
  number of static gather eqns (2 per wavelet level inside the symbol
  scan, plus table lookups); an executor rewrite that reintroduces the
  legacy dual descent doubles it.
* **no host callbacks** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` in a serving jaxpr is a host round-trip per batch.
* **no 64-bit widening** — the serving ABI is int32 indexes / float32
  scores; any f64/i64 aval means an x64 leak or an unpinned host scalar
  was folded into the program.
* **VMEM budget** — each ``pallas_call``'s block shapes must fit
  ``BACKWARD_SEARCH_VMEM_BUDGET``, and an over-budget index must provably
  fall back to XLA *at lowering time* (``backend="kernel_overbudget"``
  contracts trace with the budget clamped to 1 byte and demand zero
  launches).

``build_registry`` derives the expected numbers from the service's own
index dimensions, ``audit_service`` traces every endpoint program through
``RetrievalService.endpoint_program`` and checks the jaxprs — nothing
executes on device.
"""

from __future__ import annotations

import dataclasses

from repro.analysis import jaxpr as jx
from repro.kernels import ops

#: static gather slack on top of the 2-per-level pair-descent rank gathers:
#: pattern reversal, base/sym_starts lookups, and the Sada df counting that
#: shares the plan program (measured 4-6 on the current tree; 8 is margin
#: without room for a second descent, which would add 2 * levels)
GATHER_SLACK = 8


@dataclasses.dataclass(frozen=True)
class EndpointContract:
    """One audited (kind x bucket x backend) endpoint signature."""

    kind: str                 # "plan" | "list" | "topk" | "tfidf"
    bucket: tuple             # (batch_bucket, len_bucket)
    backend: str              # "kernel" | "xla" | "kernel_overbudget"
    pallas_calls: int         # exact whole-program launch count
    max_gathers: int | None = None    # static gather-eqn ceiling
    vmem_budget: int | None = None    # bytes per pallas_call block set
    #: collective primitives the program may contain.  () = none allowed
    #: (single-device endpoints); the sharded merge stages allowlist
    #: ("psum", "all_gather").
    collectives_allowed: tuple = ()
    #: marker for report grouping ("" = single-device, "docs" = sharded)
    mesh_axis: str = ""

    @property
    def key(self) -> str:
        pre = f"{self.mesh_axis}:" if self.mesh_axis else ""
        return (
            f"{pre}{self.kind}/B{self.bucket[0]}xm{self.bucket[1]}/"
            f"{self.backend}"
        )


@dataclasses.dataclass(frozen=True)
class Violation:
    contract: str             # EndpointContract.key (or a lint location)
    check: str                # "pallas_calls" | "gathers" | "host_callback"
    message: str              #   | "wide_dtype" | "vmem"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def pair_descent_gather_ceiling(levels: int) -> int:
    """Static gather ceiling for a planned range search: the fused (lo,
    hi) pair descent costs 2 rank gathers per wavelet level inside the
    symbol scan (loop bodies count once in a jaxpr) plus bounded table
    lookups.  The legacy dual descent costs 4 per level and must not fit."""
    return 2 * levels + GATHER_SLACK


def build_registry(svc, buckets=((1, 8), (8, 8))) -> list[EndpointContract]:
    """Contracts for every endpoint the compile cache can lower, with the
    expected numbers derived from the service's index dimensions."""
    levels = int(svc.csa.wm.words.shape[0])
    ceiling = pair_descent_gather_ceiling(levels)
    budget = ops.BACKWARD_SEARCH_VMEM_BUDGET
    # the list endpoint carries two different kernels (search + listing);
    # each pallas_call is audited against the looser of the two budgets —
    # the wrappers enforce the per-kernel number, the audit proves neither
    # launch escaped its fallback by more than the whole budget class
    list_budget = max(budget, ops.ILCP_LIST_VMEM_BUDGET)
    contracts = []
    for bucket in buckets:
        for kind in ("plan", "list", "topk"):
            gath = ceiling if kind == "plan" else None
            # kernel path: one fused backward-search launch, plus — for
            # list only — the fused ILCP listing launch (PR 6)
            launches = 2 if kind == "list" else 1
            contracts.append(EndpointContract(
                kind, bucket, "kernel", pallas_calls=launches,
                max_gathers=gath,
                vmem_budget=list_budget if kind == "list" else budget,
            ))
            contracts.append(EndpointContract(
                kind, bucket, "xla", pallas_calls=0, max_gathers=gath,
            ))
            contracts.append(EndpointContract(
                kind, bucket, "kernel_overbudget", pallas_calls=0,
            ))
        # tfidf's term range search runs batch-reshaped through the same
        # planned CSA search: its whole [Q*T] term batch is ONE fused
        # kernel launch on the kernel backend, zero on XLA / over-budget
        contracts.append(EndpointContract(
            "tfidf", bucket, "kernel", pallas_calls=1, vmem_budget=budget,
        ))
        contracts.append(EndpointContract(
            "tfidf", bucket, "xla", pallas_calls=0,
        ))
        contracts.append(EndpointContract(
            "tfidf", bucket, "kernel_overbudget", pallas_calls=0,
        ))
    return contracts


def build_sharded_registry(svc, buckets=((1, 8), (8, 8))) -> list[EndpointContract]:
    """Contracts for a docs-mesh ShardedRetrievalService: per-shard launch
    counts (the kernel path launches once PER SHARD — the unrolled
    executors each carry their own shard's wavelet matrix), and the merge
    stages may use ``psum`` / ``all_gather`` and nothing else."""
    S = svc.n_shards
    levels = max(int(sh.csa.wm.words.shape[0]) for sh in svc.shards)
    # per-shard pair descents are unrolled: S times the single-index ceiling
    ceiling = S * pair_descent_gather_ceiling(levels)
    budget = ops.BACKWARD_SEARCH_VMEM_BUDGET
    list_budget = max(budget, ops.ILCP_LIST_VMEM_BUDGET)
    allowed = ("psum", "all_gather")
    contracts = []
    for bucket in buckets:
        for kind in ("plan", "list", "topk", "tfidf"):
            gath = ceiling if kind == "plan" else None
            # list launches search + listing kernels per shard: 2 * S
            launches = 2 * S if kind == "list" else S
            contracts.append(EndpointContract(
                kind, bucket, "kernel", pallas_calls=launches,
                max_gathers=gath,
                vmem_budget=list_budget if kind == "list" else budget,
                collectives_allowed=allowed, mesh_axis="docs",
            ))
            contracts.append(EndpointContract(
                kind, bucket, "xla", pallas_calls=0, max_gathers=gath,
                collectives_allowed=allowed, mesh_axis="docs",
            ))
            contracts.append(EndpointContract(
                kind, bucket, "kernel_overbudget", pallas_calls=0,
                collectives_allowed=allowed, mesh_axis="docs",
            ))
    return contracts


def audit_jaxpr(traced, contract: EndpointContract) -> list[Violation]:
    """Check one traced endpoint against one contract.  Pure jaxpr
    inspection — nothing is compiled or executed."""
    out = []
    key = contract.key

    n_pallas = jx.count_primitive(traced, "pallas_call")
    if n_pallas != contract.pallas_calls:
        out.append(Violation(key, "pallas_calls", (
            f"expected exactly {contract.pallas_calls} pallas_call eqn(s), "
            f"found {n_pallas} — the launch-count contract of the fused "
            f"backward-search path (PR 2) is broken"
        )))

    if contract.max_gathers is not None:
        n_gather = jx.gather_count(traced)
        if n_gather > contract.max_gathers:
            out.append(Violation(key, "gathers", (
                f"{n_gather} static gather eqns exceed the pair-descent "
                f"ceiling {contract.max_gathers} — a second wavelet descent "
                f"(or per-boundary rank calls) crept back into the range "
                f"search"
            )))

    for eqn in jx.collective_eqns(traced):
        if eqn.primitive.name not in contract.collectives_allowed:
            allowed = ", ".join(contract.collectives_allowed) or "none"
            out.append(Violation(key, "collective", (
                f"collective primitive {eqn.primitive.name!r} in the "
                f"program; this endpoint allows {allowed} — merge stages "
                f"are restricted to the psum/all_gather reduction algebra"
            )))

    for eqn in jx.find_host_callbacks(traced):
        out.append(Violation(key, "host_callback", (
            f"host callback primitive {eqn.primitive.name!r} in a serving "
            f"jaxpr — every batch would pay a host round-trip; move the "
            f"logic on-device or behind the reference path"
        )))

    for eqn, dtype in jx.wide_dtype_eqns(traced):
        out.append(Violation(key, "wide_dtype", (
            f"eqn {eqn.primitive.name!r} produces {dtype} — the serving ABI "
            f"is int32/float32; pin the dtype at the source instead of "
            f"letting x64 or a host scalar widen the program"
        )))

    if contract.vmem_budget is not None:
        for eqn in jx.pallas_eqns(traced):
            est = jx.pallas_block_bytes(eqn)
            if est > contract.vmem_budget:
                out.append(Violation(key, "vmem", (
                    f"pallas_call block set is ~{est} bytes, over the "
                    f"{contract.vmem_budget}-byte VMEM budget — the wrapper "
                    f"should have taken the XLA fallback for this index"
                )))
    return out


def trace_for_contract(svc, contract: EndpointContract):
    """Trace the endpoint program a contract describes, with the backend
    forced and — for ``kernel_overbudget`` — BOTH VMEM budgets clamped so
    an over-budget index is simulated at lowering time (the list endpoint
    carries two kernels, and proving the fallback means proving both
    wrappers routed to XLA, not just the search one)."""
    B, m = contract.bucket
    use_kernel = contract.backend != "xla"
    kw = {"use_kernel": use_kernel}
    if contract.kind == "list":
        kw["use_list_kernel"] = use_kernel
    if contract.backend == "kernel_overbudget":
        saved = (ops.BACKWARD_SEARCH_VMEM_BUDGET, ops.ILCP_LIST_VMEM_BUDGET)
        ops.BACKWARD_SEARCH_VMEM_BUDGET = 1
        ops.ILCP_LIST_VMEM_BUDGET = 1
        try:
            kw["use_kernel"] = True
            if contract.kind == "list":
                kw["use_list_kernel"] = True
            return svc.trace_endpoint(contract.kind, B, m, **kw)
        finally:
            ops.BACKWARD_SEARCH_VMEM_BUDGET, ops.ILCP_LIST_VMEM_BUDGET = saved
    return svc.trace_endpoint(contract.kind, B, m, **kw)


def _csa_static_vmem_bytes(csa, buckets) -> int:
    """Static (metadata-level) VMEM estimate, independent of tracing: the
    same block layout the kernel wrapper will claim for this index."""
    wm = csa.wm
    base = csa.counts[: csa.sigma] - wm.sym_starts
    return ops.block_meta_bytes(ops.backward_search_block_meta(
        wm.words, wm.ones_prefix, wm.zcount, base,
        batch=max(b for b, _ in buckets), max_m=max(m for _, m in buckets),
    ))


def _list_static_vmem_bytes(svc, buckets, max_df: int = 64) -> int:
    """Static VMEM estimate for the fused ILCP listing kernel on this
    index: resident tables + query tiles + scratch (interval stacks and
    the distinct-document bitmap), exactly the layout
    ``ops.ilcp_list_block_meta`` describes and the wrapper gates on.
    ``max_df`` matches the audit default of ``endpoint_program``."""
    ilcp = svc.ilcp
    return ops.block_meta_bytes(ops.ilcp_list_block_meta(
        ilcp.vilcp, ilcp.rmq.table, ilcp.run_starts, svc.da,
        batch=max(b for b, _ in buckets), d=ilcp.d, max_df=max_df,
    ))


def _audit_contracts(svc, registry) -> tuple[list, list[Violation]]:
    audited, violations = [], []
    for contract in registry:
        traced = trace_for_contract(svc, contract)
        vs = audit_jaxpr(traced, contract)
        violations.extend(vs)
        audited.append({
            "contract": contract.key,
            "expected_pallas_calls": contract.pallas_calls,
            "pallas_calls": jx.count_primitive(traced, "pallas_call"),
            "gathers": jx.gather_count(traced),
            "gather_ceiling": contract.max_gathers,
            "collectives": sorted(
                {e.primitive.name for e in jx.collective_eqns(traced)}
            ),
            "vmem_block_bytes": max(
                (jx.pallas_block_bytes(e) for e in jx.pallas_eqns(traced)),
                default=0,
            ),
            "ok": not vs,
        })
    return audited, violations


def audit_service(svc, buckets=((1, 8), (8, 8))) -> tuple[dict, list[Violation]]:
    """Audit every (kind x bucket x backend) contract of a service.

    Returns (report, violations): the report lists each audited contract
    with its measured numbers (launches, gathers, VMEM estimate) so the CI
    artifact doubles as a lowering-cost trend record."""
    registry = build_registry(svc, buckets)
    violations = []
    meta_bytes = _csa_static_vmem_bytes(svc.csa, buckets)
    if meta_bytes > ops.BACKWARD_SEARCH_VMEM_BUDGET:
        violations.append(Violation(
            "index/static", "vmem",
            f"index block metadata claims ~{meta_bytes} bytes of VMEM, over "
            f"the {ops.BACKWARD_SEARCH_VMEM_BUDGET}-byte budget — kernel "
            f"launches on this index would be routed to XLA",
        ))
    list_bytes = _list_static_vmem_bytes(svc, buckets)
    if list_bytes > ops.ILCP_LIST_VMEM_BUDGET:
        violations.append(Violation(
            "index/static-list", "vmem",
            f"listing block metadata (resident + tiles + scratch) claims "
            f"~{list_bytes} bytes of VMEM, over the "
            f"{ops.ILCP_LIST_VMEM_BUDGET}-byte budget — listing kernel "
            f"launches on this index would be routed to XLA",
        ))
    audited, vs = _audit_contracts(svc, registry)
    violations.extend(vs)
    report = {
        "contracts_audited": len(registry),
        "vmem_budget_bytes": ops.BACKWARD_SEARCH_VMEM_BUDGET,
        "list_vmem_budget_bytes": ops.ILCP_LIST_VMEM_BUDGET,
        "index_static_vmem_bytes": meta_bytes,
        "list_static_vmem_bytes": list_bytes,
        "endpoints": audited,
        "violations": [v.as_dict() for v in violations],
    }
    return report, violations


def audit_sharded_service(svc, buckets=((1, 8), (8, 8))) -> tuple[dict, list[Violation]]:
    """Audit a docs-mesh ShardedRetrievalService: the per-shard launch-count
    contracts (kernel path = one ``pallas_call`` per shard), the
    psum/all_gather collective allowlist, and the per-shard static VMEM
    claims.  The per-shard VMEM check is the sharding payoff made a
    contract: each shard's wavelet matrix must fit the budget even when the
    unsharded index would not."""
    registry = build_sharded_registry(svc, buckets)
    violations = []
    shard_meta = [
        _csa_static_vmem_bytes(sh.csa, buckets) for sh in svc.shards
    ]
    for s, meta_bytes in enumerate(shard_meta):
        if meta_bytes > ops.BACKWARD_SEARCH_VMEM_BUDGET:
            violations.append(Violation(
                f"docs:shard{s}/static", "vmem",
                f"shard {s} block metadata claims ~{meta_bytes} bytes of "
                f"VMEM, over the {ops.BACKWARD_SEARCH_VMEM_BUDGET}-byte "
                f"budget — this shard's kernel launches would fall back to "
                f"XLA; use more shards",
            ))
    shard_list_meta = [
        _list_static_vmem_bytes(sh, buckets) for sh in svc.shards
    ]
    for s, meta_bytes in enumerate(shard_list_meta):
        if meta_bytes > ops.ILCP_LIST_VMEM_BUDGET:
            violations.append(Violation(
                f"docs:shard{s}/static-list", "vmem",
                f"shard {s} listing block metadata claims ~{meta_bytes} "
                f"bytes of VMEM, over the {ops.ILCP_LIST_VMEM_BUDGET}-byte "
                f"budget — this shard's listing launches would fall back "
                f"to XLA; use more shards",
            ))
    audited, vs = _audit_contracts(svc, registry)
    violations.extend(vs)
    report = {
        "mesh_axis": "docs",
        "n_shards": svc.n_shards,
        "contracts_audited": len(registry),
        "vmem_budget_bytes": ops.BACKWARD_SEARCH_VMEM_BUDGET,
        "list_vmem_budget_bytes": ops.ILCP_LIST_VMEM_BUDGET,
        "shard_static_vmem_bytes": shard_meta,
        "shard_list_static_vmem_bytes": shard_list_meta,
        "endpoints": audited,
        "violations": [v.as_dict() for v in violations],
    }
    return report, violations
