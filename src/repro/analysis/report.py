"""CLI driver: run both analysis layers, emit a JSON report, gate CI.

``python -m repro.analysis`` runs

1. the **AST lint** (``repro.analysis.lint``) over ``src/repro``, and
2. the **jaxpr contract audit** (``repro.analysis.contracts``) over every
   (kind x pow2-batch-bucket x backend) serving endpoint of a small
   synthetic index — the contracts are properties of the *programs*, not
   of the data, so a tiny collection proves them for every index that
   lowers through the same builders.

Exit status is nonzero iff any violation survived the allowlist, so the
command is a CI gate; ``--report`` writes the machine-readable JSON that
CI uploads as an artifact (it also records per-endpoint launch/gather/VMEM
numbers, so the artifact doubles as a lowering-cost trend record).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _build_audit_service():
    """A small deterministic index: big enough that every engine (brute /
    ILCP / PDL) and both range-search backends lower real programs, small
    enough to trace in seconds."""
    from repro.data.collections import SyntheticSpec, generate
    from repro.serve.retrieval import RetrievalService

    coll = generate(SyntheticSpec(
        "version", n_base=2, n_variants=4, base_len=60,
        mutation_rate=0.01, seed=7,
    ))
    return RetrievalService.build(coll, validate=False)


def run(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis gate: jaxpr contract audit + AST lint",
    )
    ap.add_argument("--report", type=pathlib.Path, default=None,
                    help="write the JSON report here (CI artifact)")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="tree to lint (default: the repro package itself)")
    ap.add_argument("--buckets", default="1,8",
                    help="comma-separated batch buckets to audit")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the (slower) jaxpr contract audit")
    args = ap.parse_args(argv)

    from repro.analysis import lint as lint_mod

    root = args.root or pathlib.Path(__file__).resolve().parents[1]
    lint_violations, lint_stats = lint_mod.lint_tree(root)
    report = {
        "lint": {
            **lint_stats,
            "violations": [v.as_dict() for v in lint_violations],
        },
    }

    contract_violations = []
    if not args.lint_only:
        import jax

        from repro.analysis.contracts import (
            audit_service,
            audit_sharded_service,
        )

        buckets = tuple(
            (int(b), 8) for b in args.buckets.split(",") if b.strip()
        )
        svc = _build_audit_service()
        contracts_report, contract_violations = audit_service(
            svc, buckets=buckets
        )
        report["contracts"] = contracts_report

        # sharded contracts need >1 device: audited when the host is
        # virtualized (XLA_FLAGS=--xla_force_host_platform_device_count=N,
        # the CI sharded-smoke step), skipped on a single-device host
        if jax.device_count() >= 2:
            from repro.dist.sharding import make_docs_mesh
            from repro.serve.retrieval import RetrievalService

            mesh = make_docs_mesh(min(4, jax.device_count()))
            sharded = RetrievalService.build(
                svc.coll, mesh=mesh, validate=False
            )
            sh_report, sh_violations = audit_sharded_service(
                sharded, buckets=buckets
            )
            report["contracts_sharded"] = sh_report
            contract_violations = contract_violations + sh_violations

    n_bad = len(lint_violations) + len(contract_violations)
    report["ok"] = n_bad == 0

    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(json.dumps(report, indent=2, sort_keys=True))

    for v in lint_violations:
        print(f"{v.location} {v.rule} [{v.qualname}] {v.message}\n"
              f"    fix: {v.fixit}", file=sys.stderr)
    for v in contract_violations:
        print(f"{v.contract} {v.check}: {v.message}", file=sys.stderr)
    if n_bad:
        print(f"repro.analysis: {n_bad} violation(s)", file=sys.stderr)
        return 1
    audited = report.get("contracts", {}).get("contracts_audited", 0)
    print(f"repro.analysis: clean "
          f"({lint_stats['files_scanned']} files linted, "
          f"{audited} endpoint contracts audited)")
    return 0
