"""Jaxpr walking primitives for the static contract auditor.

The serving stack's performance contracts (one ``pallas_call`` per planned
batch, bounded gather counts on the XLA fallback, no host callbacks, no
silent 64-bit widening) are all visible in the jaxpr of a traced endpoint
— *before* anything runs.  This module is the walker those audits share.

``jax.core.subjaxprs`` only yields the jaxprs it can see in an eqn's
params and does not descend recursively, so a counter built directly on it
misses primitives nested two levels deep (a ``pallas_call`` inside a
``pjit`` inside a ``scan``, or the branches of a ``cond`` inside a
``custom_vjp`` residual).  ``iter_eqns`` here does its own recursive
descent over every ``Jaxpr``/``ClosedJaxpr`` reachable through eqn params
— including params that hold them inside tuples, lists, or dicts (``cond``
branches, ``custom_vjp`` fun/fwd jaxprs, ``pjit``'s ``jaxpr`` param) — so
every count is a whole-program count.
"""

from __future__ import annotations

import math

import jax
import numpy as np

#: primitives that re-enter the host mid-program; forbidden in any serving
#: jaxpr (a host round-trip inside a batched endpoint defeats the entire
#: on-device engine and is invisible to wall-clock tests at small scale)
HOST_CALLBACK_PRIMITIVES = (
    "pure_callback",
    "io_callback",
    "debug_callback",
    "host_callback_call",
)

#: dtypes that indicate silent widening downstream of the int32/float32
#: serving ABI (x64 mode leaking in, or a Python float folded as f64)
WIDE_DTYPES = ("int64", "uint64", "float64", "complex128")


def _as_jaxpr(obj):
    """Accept ``Jaxpr``, ``ClosedJaxpr``, or anything with ``.jaxpr``."""
    while hasattr(obj, "jaxpr"):
        obj = obj.jaxpr
    return obj


def _jaxprs_in(value):
    """Yield every jaxpr held (possibly nested in containers) in a param
    value — ``cond`` stores a tuple of ClosedJaxprs, ``pjit`` a single
    ClosedJaxpr, pallas a raw Jaxpr."""
    if isinstance(value, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
        yield _as_jaxpr(value)
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _jaxprs_in(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _jaxprs_in(v)


def iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` and in every jaxpr nested in eqn params, at
    any depth (pjit / scan / while / cond / custom_vjp / pallas_call)."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _jaxprs_in(eqn.params):
            yield from iter_eqns(sub)


def count_primitive(jaxpr, name: str) -> int:
    """Whole-program occurrence count of a primitive by name.

    Replaces the hand-rolled ``count_eqns`` from tests/test_kernels.py:
    that version descended only via ``jax.core.subjaxprs`` and could miss
    jaxprs nested inside eqn params of ``pjit``/``custom_vjp`` calls."""
    return sum(1 for eqn in iter_eqns(jaxpr) if eqn.primitive.name == name)


def find_primitives(jaxpr, names) -> list:
    """All eqns (any depth) whose primitive name is in ``names``."""
    names = set(names)
    return [eqn for eqn in iter_eqns(jaxpr) if eqn.primitive.name in names]


def find_host_callbacks(jaxpr) -> list:
    return find_primitives(jaxpr, HOST_CALLBACK_PRIMITIVES)


def gather_count(jaxpr) -> int:
    """Static ``gather`` eqn count (loop bodies count once — this is a
    program-structure metric, not a per-element op count)."""
    return count_primitive(jaxpr, "gather")


#: cross-device communication primitives.  The sharded serving programs
#: allowlist ``psum`` / ``all_gather`` in their merge stages; anything else
#: (or any collective in a single-device program) is a contract violation —
#: an accidental ``all_to_all`` or ``ppermute`` in a merge is a silent
#: bandwidth regression no correctness test notices.
COLLECTIVE_PRIMITIVES = (
    "psum",
    "pmax",
    "pmin",
    "all_gather",
    "all_to_all",
    "ppermute",
    "pshuffle",
    "reduce_scatter",
    "pbroadcast",
    "pgather",
)


def collective_eqns(jaxpr) -> list:
    """All cross-device collective eqns at any depth (shard_map bodies
    included — ``iter_eqns`` descends through the shard_map eqn's jaxpr
    param)."""
    return find_primitives(jaxpr, COLLECTIVE_PRIMITIVES)


def wide_dtype_eqns(jaxpr) -> list:
    """(eqn, dtype) for every eqn producing a 64-bit output.

    The serving ABI is int32 indexes and float32 scores end to end; any
    f64/i64 aval in a serving jaxpr is silent widening (x64 leak, a
    ``np.float64`` scalar folded into a traced expression, or an unpinned
    host-side accumulator crossing into the program)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) in WIDE_DTYPES:
                out.append((eqn, str(dtype)))
                break
    return out


# ---------------------------------------------------------------------------
# pallas_call inspection
# ---------------------------------------------------------------------------


def pallas_eqns(jaxpr) -> list:
    return find_primitives(jaxpr, ("pallas_call",))


def pallas_scratch_bytes(eqn) -> int:
    """Bytes of every ``scratch_shapes`` operand of one ``pallas_call``
    eqn.  ``grid_mapping.block_mappings`` covers only in/out operands, so
    scratch is invisible to a block-shape walk — but the kernel jaxpr's
    invars carry the scratch refs as its trailing parameters, and their
    MemRef avals keep the allocated shape/dtype.  ``num_scratch_operands``
    on the grid mapping says how many of the tail to take."""
    gm = eqn.params.get("grid_mapping")
    kernel = eqn.params.get("jaxpr")
    n_scratch = getattr(gm, "num_scratch_operands", 0) if gm else 0
    if not n_scratch or kernel is None:
        return 0
    total = 0
    for var in _as_jaxpr(kernel).invars[-n_scratch:]:
        aval = var.aval
        total += (
            int(math.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
        )
    return total


def pallas_block_bytes(eqn) -> int:
    """Static VMEM estimate for one ``pallas_call`` eqn: the bytes of every
    operand/result *block* (the per-grid-step resident set), read from the
    eqn's ``grid_mapping`` block shapes, PLUS the kernel's scratch
    allocations (``pallas_scratch_bytes`` — the fused listing kernel's
    interval stacks and distinct-document bitmap live there, and leaving
    them out would undercount its grid step by the whole working set).

    This is the lowering-time counterpart of the runtime budget checks in
    ``repro.kernels.ops``: if this estimate exceeds the relevant budget the
    kernel was launched on an index the wrapper should have routed to the
    XLA fallback."""
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return 0
    total = 0
    for bm in gm.block_mappings:
        shape = [d for d in bm.block_shape if isinstance(d, (int, np.integer))]
        sds = getattr(bm, "array_shape_dtype", None)
        itemsize = np.dtype(sds.dtype).itemsize if sds is not None else 4
        total += int(math.prod(shape)) * itemsize
    return total + pallas_scratch_bytes(eqn)
