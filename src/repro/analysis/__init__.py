"""Static analysis for the serving stack: jaxpr contract audits + AST lint.

Two layers, one CI gate (``python -m repro.analysis``):

* :mod:`repro.analysis.jaxpr` / :mod:`repro.analysis.contracts` — trace
  every compiled serving endpoint and check launch counts, gather
  ceilings, host-callback and 64-bit-widening bans, and the static VMEM
  budget, all at lowering time;
* :mod:`repro.analysis.lint` — repo-specific AST rules (injectable clocks,
  no host sync in batched executors, registered fault sites only, no
  import-time jit execution).
"""

from repro.analysis.jaxpr import (
    count_primitive,
    find_host_callbacks,
    find_primitives,
    gather_count,
    iter_eqns,
    pallas_block_bytes,
    pallas_eqns,
    wide_dtype_eqns,
)

__all__ = [
    "count_primitive",
    "find_host_callbacks",
    "find_primitives",
    "gather_count",
    "iter_eqns",
    "pallas_block_bytes",
    "pallas_eqns",
    "wide_dtype_eqns",
]
