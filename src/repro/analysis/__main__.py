import sys

from repro.analysis.report import run

sys.exit(run())
