"""Query planner — stage 1 of the batched on-device query engine.

The paper's experimental recommendation (Section 6.2.2) is a *dispatch
policy*: document frequency df is cheap to compute first (Sada), occ =
hi - lo falls out of the CSA range search, and the listing engine is chosen
by their ratio — Brute-L when occ/df is below a threshold (~4 on the
paper's hardware), the precomputed machinery (PDL) otherwise.

This module turns that policy into a fully traced program: one fused pass
over a padded pattern batch computes ``(lo, hi)`` (CSA backward search),
``df`` (Sadakane counting), ``occ``, and a per-query **engine assignment**
as an int32 array — no host branching anywhere.  The masked batch executors
(stage 2, ``repro.core.*``) then run every engine over its sub-batch under
``jnp.where`` masking, and the serving layer (stage 3,
``repro.serve.retrieval``) compiles planner + executors into a single
program per shape bucket.

Engine codes are part of the serving ABI (they appear in plans returned to
callers): 0 = empty range, 1 = Brute-L, 2 = ILCP (Sada-I-D), 3 = PDL.
``forced_engine`` is a *traced* scalar (-1 = auto), so switching the engine
mode does not recompile the program.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.common import IDX, as_i32, pytree_dataclass
from repro.core.csa import CSA, csa_search_planned
from repro.core.sada import SadaCount, sada_count_batch

ENGINE_EMPTY = 0
ENGINE_BRUTE = 1
ENGINE_ILCP = 2
ENGINE_PDL = 3

#: public engine names -> forced-engine codes (-1 lets the planner decide)
ENGINE_CODES = {
    "auto": -1,
    "brute": ENGINE_BRUTE,
    "ilcp": ENGINE_ILCP,
    "pdl": ENGINE_PDL,
}


@pytree_dataclass
class QueryPlan:
    """Per-query execution plan (all int32[B] device arrays)."""

    lo: jnp.ndarray
    hi: jnp.ndarray
    occ: jnp.ndarray
    df: jnp.ndarray
    engine: jnp.ndarray


def plan_queries(
    csa: CSA,
    sada: SadaCount,
    patterns: jnp.ndarray,     # int32[B, max_m] padded patterns
    lengths: jnp.ndarray,      # int32[B] true lengths (0 = padding row)
    occ_df_threshold,          # traced f32 scalar
    forced_engine,             # traced i32 scalar; -1 = auto dispatch
    *,
    use_kernel: bool | None = None,
) -> QueryPlan:
    """One fused pass: ranges + df + occ + engine assignment.

    Rows with length 0 (batch padding) and patterns with no occurrences get
    ``ENGINE_EMPTY``; executors skip them under masking and the serving
    layer reports them as empty results.  ``use_kernel`` selects the range
    search's execution path: the fused Pallas backward-search kernel (one
    launch per batch — the TPU hot path) or the XLA pair-descent fallback;
    ``None`` auto-detects the backend (kernel iff TPU).
    """
    lengths = as_i32(lengths)
    lo, hi = csa_search_planned(
        csa, as_i32(patterns), lengths, use_kernel=use_kernel
    )
    hi = jnp.where(lengths > 0, hi, lo)  # padding rows: empty range
    occ = hi - lo
    df = sada_count_batch(sada, lo, hi)

    thresh = jnp.asarray(occ_df_threshold, jnp.float32)
    auto = jnp.where(
        occ.astype(jnp.float32) < thresh * jnp.maximum(df, 1).astype(jnp.float32),
        ENGINE_BRUTE,
        ENGINE_PDL,
    ).astype(IDX)
    forced = as_i32(forced_engine)
    engine = jnp.where(forced >= 0, forced, auto)
    engine = jnp.where(occ > 0, engine, ENGINE_EMPTY).astype(IDX)
    return QueryPlan(lo=lo, hi=hi, occ=occ, df=df, engine=engine)


def masked_ranges(plan: QueryPlan, engine_code: int):
    """(lo, hi) with every query not assigned to ``engine_code`` collapsed
    to the empty range (0, 0) — the masking contract of the batch
    executors: an empty range costs one loop iteration and reports
    nothing."""
    sel = plan.engine == engine_code
    return jnp.where(sel, plan.lo, 0), jnp.where(sel, plan.hi, 0)
