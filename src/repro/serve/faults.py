"""Deterministic fault injection for the serving stack.

Every degradation path in ``repro.serve.runtime`` must be *testable on
demand*: this module installs context-manager hooks at the instrumented
sites of ``RetrievalService`` so that planner, executor, and compile calls
raise, hang, or return poisoned sentinels on a seeded schedule.

Instrumented sites (prefix-matched, ``:``-separated segments):

    plan                 the planner program (ranges + df + engine)
    executor:list        the fused listing program
    executor:topk        the fused top-k program
    executor:tfidf       the fused ranked multi-term program
    compile:<kind>       AOT lowering/compilation of a new shape bucket

The ``engine="reference"`` host loop is deliberately NOT instrumented — it
is the runtime's last-resort degradation target and must stay fault-free.

Fault kinds:

    error    raise :class:`repro.errors.FaultInjectedError` (a
             ``TransientExecutionError``) before the site runs
    hang     sleep ``hang_s`` seconds before the site runs (a simulated
             slow device/compile; the caller's deadline accounting sees
             the real elapsed time)
    poison   let the site run, then overwrite its output arrays with the
             ``POISON`` sentinel — exercises the runtime's payload
             validation (a poisoned answer must never reach a caller)

Schedules are deterministic: each ``FaultSpec`` draws from its own
``random.Random(seed)`` stream, one draw per matching call, so a workload
replayed against the same specs fires the same faults at the same calls.

Usage::

    with faults.inject(FaultSpec("executor", "error", rate=0.1)) as inj:
        runtime.serve(requests)
    assert inj.fired            # [(site, kind, call_ordinal), ...]
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import time

import numpy as np

from repro.errors import FaultInjectedError

#: sentinel written over poisoned output arrays — outside every legal value
#: range of the serving ABI (doc ids are >= -1), so payload validation in
#: the runtime must reject it
POISON = np.int32(-0xBAD)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault schedule: fire ``kind`` at sites matching ``site`` with
    probability ``rate`` per call (seeded, deterministic), at most
    ``limit`` times (None = unlimited)."""

    site: str
    kind: str                    # "error" | "hang" | "poison"
    rate: float = 0.1
    hang_s: float = 0.05
    seed: int = 0
    limit: int | None = None

    def __post_init__(self):
        if self.kind not in ("error", "hang", "poison"):
            raise ValueError(f"unknown fault kind: {self.kind!r}")

    def matches(self, site: str) -> bool:
        return site == self.site or site.startswith(self.site + ":")


#: named shortcuts accepted by ``--inject`` flags (benchmarks, launcher):
#: ``name[:rate]`` comma-separated, e.g. ``executor_fail:0.2,slow_pdl``
NAMED_FAULTS = {
    "executor_fail": ("executor", "error"),
    "executor_poison": ("executor", "poison"),
    "slow_pdl": ("executor:topk", "hang"),   # PDL-backed top-k is the slow path
    "slow_list": ("executor:list", "hang"),
    "planner_fail": ("plan", "error"),
    "compile_error": ("compile", "error"),
}


def parse_fault_specs(arg: str, rate: float = 0.1, hang_s: float = 0.05,
                      seed: int = 0):
    """Parse an ``--inject`` flag value into FaultSpecs.

    ``arg`` is a comma-separated list of names from :data:`NAMED_FAULTS`,
    each with an optional ``:rate`` suffix.  Each spec gets its own seed
    offset so schedules stay independent."""
    specs = []
    for i, tok in enumerate(t for t in arg.split(",") if t.strip()):
        name, _, rate_s = tok.strip().partition(":")
        if name not in NAMED_FAULTS:
            raise ValueError(
                f"unknown fault {name!r}; known: {sorted(NAMED_FAULTS)}"
            )
        site, kind = NAMED_FAULTS[name]
        specs.append(
            FaultSpec(site=site, kind=kind, rate=float(rate_s or rate),
                      hang_s=hang_s, seed=seed + i)
        )
    return specs


class FaultInjector:
    """Holds the active schedules and the firing log."""

    def __init__(self, *specs: FaultSpec, sleep=time.sleep):
        self.specs = specs
        self._sleep = sleep
        self._rngs = [random.Random(s.seed) for s in specs]
        self._fire_counts = [0] * len(specs)
        self.calls = 0               # instrumented calls observed
        self.fired: list = []        # (site, kind, call ordinal)

    def _due(self, idx: int, spec: FaultSpec, site: str) -> bool:
        if not spec.matches(site):
            return False
        if spec.limit is not None and self._fire_counts[idx] >= spec.limit:
            return False
        # one draw per *matching* call keeps the schedule independent of
        # what other sites do between matches
        if self._rngs[idx].random() >= spec.rate:
            return False
        self._fire_counts[idx] += 1
        self.fired.append((site, spec.kind, self.calls))
        return True

    def fire(self, site: str) -> None:
        """Called before an instrumented site runs; may raise or hang."""
        self.calls += 1
        for idx, spec in enumerate(self.specs):
            if spec.kind == "poison" or not self._due(idx, spec, site):
                continue
            if spec.kind == "hang":
                self._sleep(spec.hang_s)
            else:
                raise FaultInjectedError(site, len(self.fired))

    def poison(self, site: str, arrays: tuple) -> tuple:
        """Called on an instrumented site's output; may replace arrays with
        the POISON sentinel (integer arrays only — shapes preserved)."""
        for idx, spec in enumerate(self.specs):
            if spec.kind != "poison" or not self._due(idx, spec, site):
                continue
            return tuple(
                np.full_like(np.asarray(a), POISON)
                if np.issubdtype(np.asarray(a).dtype, np.integer)
                else np.asarray(a)
                for a in arrays
            )
        return arrays


#: the active injector; None = all hooks are no-ops (the production path
#: pays one attribute load + is-None test per instrumented call)
_ACTIVE: FaultInjector | None = None


@contextlib.contextmanager
def inject(*specs: FaultSpec, sleep=time.sleep):
    """Install fault schedules for the duration of the block (not
    reentrant — nested injectors replace, then restore, the outer one)."""
    global _ACTIVE
    prev = _ACTIVE
    inj = FaultInjector(*specs, sleep=sleep)
    _ACTIVE = inj
    try:
        yield inj
    finally:
        _ACTIVE = prev


def active() -> FaultInjector | None:
    return _ACTIVE


def fire(site: str) -> None:
    """Site hook: raise/hang per the active schedules (no-op when none)."""
    if _ACTIVE is not None:
        _ACTIVE.fire(site)


def poison(site: str, arrays: tuple) -> tuple:
    """Output hook: maybe overwrite ``arrays`` with POISON sentinels."""
    if _ACTIVE is not None:
        return _ACTIVE.poison(site, arrays)
    return arrays
