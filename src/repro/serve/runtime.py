"""Resilient request-execution runtime over :class:`RetrievalService`.

The batched engine (PR 1-2) fails the way a research script fails: one
malformed pattern, one over-budget compile, or one slow PDL query takes the
whole batch — and the process — down with it.  This module wraps the
service in a serving-grade execution layer with one contract:

    **every admitted request gets an answer** — possibly degraded, always
    flagged — **within its deadline plus at most one batch interval.**

Architecture
------------

* **Bounded admission queue** (``submit`` / ``QueueFullError``): requests
  carry absolute deadlines; batches are cut earliest-deadline-first, one
  endpoint kind per batch, sized to a power of two (the compile-bucket
  contract of ``serve.retrieval``) and *shrunk* when the steady-state
  latency estimate for that (kind, bucket) would blow the earliest
  deadline's slack.
* **Retry with backoff**: a failed execution attempt (device error,
  injected fault, poisoned payload) is retried up to
  ``RuntimeConfig.max_retries`` times with exponential backoff.
* **Circuit breaker per (kind, bucket)**: attempts exhausted count as one
  breaker failure; ``breaker_threshold`` consecutive failures trip the
  bucket OPEN and the runtime stops *trying* the full path — it degrades
  immediately instead of failing slowly.
* **Graceful degradation ladder**: (1) force the cheap Brute-L engine with
  ``max_df``/``k`` clamped to the floor bucket; (2) fall back to
  ``engine="reference"`` on host (deliberately not fault-instrumented);
  (3) as a last resort answer empty.  Every degraded answer is flagged
  with ``Answer.degraded`` and a ``cause:path`` reason string.
* **Payload validation**: executor outputs are checked against the serving
  ABI (doc ids in ``[-1, d)``, counts within ``[0, max_df]``) before they
  are formatted, so a poisoned sentinel is a retryable failure, never an
  answer.

Error taxonomy (see :mod:`repro.errors`)
----------------------------------------

* ``InvalidQueryError`` — structurally bad input (non-pattern payload);
  raised from ``submit`` at admission time.  Soft-invalid input (empty /
  over-long / out-of-alphabet patterns) is admitted and answers empty.
* ``QueueFullError`` — admission queue at capacity; the only load-shedding
  exception.
* ``TransientExecutionError`` (incl. ``FaultInjectedError``,
  ``PoisonedResultError``) — a single attempt failed; consumed internally
  by the retry/breaker machinery, never surfaced to callers.
* ``DeadlineExceeded`` — never raised to callers by this runtime; it is
  converted into an answer with ``deadline_missed=True`` (degraded-empty
  if the deadline passed while still queued, late-but-real if execution
  overran).  The class exists for strict async front-ends that prefer an
  exception over a flag.

Circuit-breaker state machine (per (kind, bucket) key)
------------------------------------------------------

::

            success                 failure x threshold
    CLOSED ─────────▶ CLOSED      CLOSED ───────────────▶ OPEN
                                                           │ cooldown_s
       ◀── success ── HALF_OPEN ◀──────────────────────────┘
       └── failure ──▶ OPEN  (cooldown restarts)

While OPEN, the full path is skipped entirely (``short_circuits`` metric)
and answers come from the degradation ladder with cause ``breaker_open``.
After ``breaker_cooldown_s`` the next batch probes the full path
(HALF_OPEN): success closes the breaker, failure re-opens it immediately
(no threshold accumulation).
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import Counter, deque

import numpy as np

from repro.data.collections import normalize_patterns
from repro.errors import (
    InvalidQueryError,
    PoisonedResultError,
    QueueFullError,
)
from repro.serve.retrieval import MAX_PATTERN_LEN

KINDS = ("list", "topk", "count", "tfidf")

#: deadline-slack safety factor for batch shrinking: predicted latency must
#: fit within slack * this before we commit a batch size
_SLACK_SAFETY = 0.8
_EMA_ALPHA = 0.3


def _pow2_ceil(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    max_queue: int = 1024
    max_batch: int = 64
    default_deadline_s: float = 0.5
    #: deadline-miss tolerance unit: the contract is deadline + one batch
    #: interval, where the interval is the steady-state batch latency
    max_retries: int = 2
    backoff_base_s: float = 0.002
    backoff_factor: float = 2.0
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 1.0
    # full-path knobs
    k: int = 10
    max_df: int = 256
    max_buf: int = 1024
    tfidf_conjunctive: bool = False
    # degraded floor bucket
    floor_k: int = 4
    floor_max_df: int = 16


@dataclasses.dataclass
class Request:
    rid: int
    kind: str
    payload: object              # normalized pattern (or term list for tfidf)
    deadline: float | None       # absolute clock() time
    submitted_at: float


@dataclasses.dataclass
class Answer:
    rid: int
    kind: str
    result: object               # list | [(doc, tf)] | int | [(doc, score)]
    degraded: bool = False
    degrade_reason: str | None = None   # "cause:path", e.g. "breaker_open:floor"
    deadline_missed: bool = False
    overrun_s: float = 0.0       # how far past the deadline the answer landed
    latency_s: float = 0.0       # submit -> answer
    retries: int = 0
    path: str = "full"           # "full" | "floor" | "reference" | "empty"


@dataclasses.dataclass
class RuntimeMetrics:
    submitted: int = 0
    rejected: int = 0            # QueueFullError
    invalid: int = 0             # InvalidQueryError at admission
    answered: int = 0
    degraded: int = 0
    deadline_misses: int = 0
    max_overrun_s: float = 0.0
    retries: int = 0
    failures: int = 0            # attempts exhausted on a batch
    breaker_trips: int = 0
    short_circuits: int = 0      # batches skipped past the full path
    batches: int = 0
    degrade_reasons: Counter = dataclasses.field(default_factory=Counter)
    #: first-execution (compile-heavy) latency per (kind, bucket) — kept
    #: out of the steady-state EMA so percentiles stay honest
    compile_s: dict = dataclasses.field(default_factory=dict)
    steady_ema_s: dict = dataclasses.field(default_factory=dict)

    @property
    def degraded_fraction(self) -> float:
        return self.degraded / self.answered if self.answered else 0.0

    @property
    def deadline_miss_rate(self) -> float:
        return self.deadline_misses / self.answered if self.answered else 0.0

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["degrade_reasons"] = dict(self.degrade_reasons)
        out["compile_s"] = {f"{k}/{b}": round(v, 4)
                            for (k, b), v in self.compile_s.items()}
        out["steady_ema_s"] = {f"{k}/{b}": round(v, 4)
                               for (k, b), v in self.steady_ema_s.items()}
        out["degraded_fraction"] = round(self.degraded_fraction, 4)
        out["deadline_miss_rate"] = round(self.deadline_miss_rate, 4)
        return out


class CircuitBreaker:
    """Per-key breaker implementing the module-docstring state machine."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int, cooldown_s: float, clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._st: dict = {}      # key -> [state, consecutive_failures, opened_at]
        self.trips = 0

    def _entry(self, key):
        return self._st.setdefault(key, [self.CLOSED, 0, 0.0])

    def allow(self, key) -> str:
        """Effective state for the next attempt; OPEN past its cooldown
        transitions to HALF_OPEN (one probe allowed)."""
        e = self._entry(key)
        if e[0] == self.OPEN and self._clock() - e[2] >= self.cooldown_s:
            e[0] = self.HALF_OPEN
        return e[0]

    def record_success(self, key) -> None:
        self._st[key] = [self.CLOSED, 0, 0.0]

    def record_failure(self, key) -> bool:
        """Returns True when this failure trips (or re-trips) the breaker."""
        e = self._entry(key)
        e[1] += 1
        if e[0] == self.HALF_OPEN or e[1] >= self.threshold:
            e[0] = self.OPEN
            e[2] = self._clock()
            e[1] = 0
            self.trips += 1
            return True
        return False

    def state(self, key) -> str:
        return self._entry(key)[0]


class ServeRuntime:
    """Deadline-aware, fault-tolerant front of a RetrievalService.

    ``clock`` and ``sleep`` are injectable for deterministic tests (the
    breaker cooldown and retry backoff run on the same clock)."""

    def __init__(self, svc, config: RuntimeConfig | None = None, *,
                 clock=time.monotonic, sleep=time.sleep):
        self.svc = svc
        self.config = config or RuntimeConfig()
        self._clock = clock
        self._sleep = sleep
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self.breaker = CircuitBreaker(
            self.config.breaker_threshold, self.config.breaker_cooldown_s,
            clock=clock,
        )
        self.metrics = RuntimeMetrics()

    # -- admission -----------------------------------------------------------

    def submit(self, kind: str, payload, *, deadline_s: float | None = None) -> int:
        """Admit one request; returns its id.  Raises InvalidQueryError for
        structurally bad payloads and QueueFullError at capacity — the only
        two exceptions this runtime surfaces."""
        if kind not in KINDS:
            self.metrics.invalid += 1
            raise InvalidQueryError(f"unknown endpoint kind {kind!r}")
        if len(self._queue) >= self.config.max_queue:
            self.metrics.rejected += 1
            raise QueueFullError(
                f"admission queue at capacity ({self.config.max_queue})"
            )
        sigma = self.svc.coll.sigma
        try:
            if kind == "tfidf":
                if isinstance(payload, (str, bytes, np.ndarray)) or not hasattr(
                    payload, "__iter__"
                ):
                    raise InvalidQueryError(
                        "tfidf payload must be a list of term patterns"
                    )
                norm = normalize_patterns(
                    list(payload), sigma=sigma, max_len=MAX_PATTERN_LEN
                )
            else:
                norm = normalize_patterns(
                    [payload], sigma=sigma, max_len=MAX_PATTERN_LEN
                )[0]
        except InvalidQueryError:
            self.metrics.invalid += 1
            raise
        now = self._clock()
        ddl = self.config.default_deadline_s if deadline_s is None else deadline_s
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(
            rid=rid, kind=kind, payload=norm,
            deadline=(now + ddl) if ddl is not None else None,
            submitted_at=now,
        ))
        self.metrics.submitted += 1
        return rid

    # -- batch cutting -------------------------------------------------------

    def _cut_batch(self, now: float) -> list[Request]:
        """Earliest-deadline-first, one kind per batch, power-of-two sized,
        shrunk while the steady-state estimate would blow the head's
        slack."""
        if not self._queue:
            return []
        order = sorted(
            self._queue,
            key=lambda r: (r.deadline if r.deadline is not None else math.inf, r.rid),
        )
        head = order[0]
        batch = [r for r in order if r.kind == head.kind][: self.config.max_batch]
        # Python float, not np.inf: a numpy float64 scalar here would leak
        # into every latency comparison below (the dtype-width audit's
        # host-side counterpart — the clock path stays pure Python floats)
        slack = float(head.deadline - now) if head.deadline is not None else math.inf
        while len(batch) > 1:
            est = self.metrics.steady_ema_s.get((head.kind, _pow2_ceil(len(batch))))
            if est is None or est <= max(slack, 0.0) * _SLACK_SAFETY:
                break
            batch = batch[: max(1, len(batch) // 2)]
        chosen = {r.rid for r in batch}
        self._queue = deque(r for r in self._queue if r.rid not in chosen)
        return batch

    # -- endpoint plumbing ---------------------------------------------------

    def _call(self, kind: str, reqs: list[Request], path: str):
        cfg = self.config
        pats = [r.payload for r in reqs]
        svc = self.svc
        if path == "reference":
            # host per-query loop: slow, compile-free, not fault-instrumented
            if kind == "list":
                return svc.list_docs(pats, max_df=cfg.max_df, engine="reference",
                                     max_buf=cfg.max_buf)
            if kind == "topk":
                return svc.topk(pats, k=cfg.k, engine="reference",
                                max_buf=cfg.max_buf)
            if kind == "count":
                return [int(x) for x in svc.count(pats, engine="reference")]
            return svc.tfidf(pats, k=cfg.k, conjunctive=cfg.tfidf_conjunctive,
                             max_buf=cfg.max_buf, engine="reference")

        floor = path == "floor"
        if kind == "list":
            max_df = cfg.floor_max_df if floor else cfg.max_df
            docs, cnt = svc.list_docs_arrays(
                pats, max_df=max_df, engine="brute" if floor else "auto",
                max_buf=cfg.max_buf,
            )
            self._check_docs(docs, cnt, max_df)
            return [docs[i, : cnt[i]].tolist() for i in range(len(reqs))]
        if kind == "topk":
            k = cfg.floor_k if floor else cfg.k
            docs, tfs = svc.topk_arrays(
                pats, k=k, engine="brute" if floor else "auto",
                max_buf=cfg.max_buf,
            )
            self._check_docs(docs, None, k)
            return [
                [(int(d), int(t)) for d, t in zip(docs[i], tfs[i]) if d >= 0]
                for i in range(len(reqs))
            ]
        if kind == "count":
            df = np.asarray(svc.count(pats))
            if df.size and (df.min() < 0 or df.max() > svc.coll.d):
                raise PoisonedResultError("df outside [0, d]")
            return [int(x) for x in df]
        k = cfg.floor_k if floor else cfg.k
        docs, scores = svc.tfidf_arrays(
            pats, k=k, conjunctive=cfg.tfidf_conjunctive, max_buf=cfg.max_buf
        )
        self._check_docs(docs, None, k)
        return [
            [(int(d), float(s)) for d, s in zip(docs[i], scores[i]) if d >= 0]
            for i in range(len(reqs))
        ]

    def _check_docs(self, docs, cnt, max_df) -> None:
        """Serving-ABI payload validation: a poisoned sentinel or an
        out-of-range id is an execution failure, never an answer."""
        docs = np.asarray(docs)
        if docs.size and (docs.min() < -1 or docs.max() >= self.svc.coll.d):
            raise PoisonedResultError("doc id outside [-1, d)")
        if cnt is not None:
            cnt = np.asarray(cnt)
            if cnt.size and (cnt.min() < 0 or cnt.max() > max_df):
                raise PoisonedResultError("listing count outside [0, max_df]")

    # -- execution core ------------------------------------------------------

    def _execute_batch(self, reqs: list[Request]) -> list[Answer]:
        cfg, m = self.config, self.metrics
        kind = reqs[0].kind
        key = (kind, _pow2_ceil(len(reqs)))
        m.batches += 1
        start = self._clock()
        results, path, reason, retries = None, "full", None, 0

        state = self.breaker.allow(key)
        if state == CircuitBreaker.OPEN:
            m.short_circuits += 1
            cause = "breaker_open"
        else:
            backoff = cfg.backoff_base_s
            for attempt in range(cfg.max_retries + 1):
                try:
                    results = self._call(kind, reqs, "full")
                    self.breaker.record_success(key)
                    break
                except Exception:
                    retries += 1
                    m.retries += 1
                    if attempt < cfg.max_retries:
                        self._sleep(backoff)
                        backoff *= cfg.backoff_factor
            else:
                m.failures += 1
                if self.breaker.record_failure(key):
                    m.breaker_trips += 1
            cause = "retries_exhausted"

        if results is None:
            for path in ("floor", "reference"):
                try:
                    results = self._call(kind, reqs, path)
                    reason = f"{cause}:{path}"
                    break
                except Exception:
                    continue
            else:
                path = "empty"
                reason = f"{cause}:empty"
                results = [0 if kind == "count" else [] for _ in reqs]

        end = self._clock()
        # injected clocks may hand back numpy scalars; the EMA and every
        # overrun/latency figure below must stay Python floats or the
        # widened dtype propagates into reported metrics arrays
        elapsed = float(end - start)
        if key not in m.compile_s and path == "full":
            m.compile_s[key] = elapsed     # first run pays the AOT compile
        elif path == "full":
            prev = m.steady_ema_s.get(key)
            m.steady_ema_s[key] = (
                elapsed if prev is None
                else float((1 - _EMA_ALPHA) * prev + _EMA_ALPHA * elapsed)
            )

        answers = []
        for r, res in zip(reqs, results):
            overrun = (
                max(0.0, float(end - r.deadline))
                if r.deadline is not None else 0.0
            )
            ans = Answer(
                rid=r.rid, kind=kind, result=res,
                degraded=path != "full", degrade_reason=reason,
                deadline_missed=overrun > 0, overrun_s=overrun,
                latency_s=float(end - r.submitted_at), retries=retries,
                path=path,
            )
            self._account(ans)
            answers.append(ans)
        return answers

    def _account(self, ans: Answer) -> None:
        m = self.metrics
        m.answered += 1
        if ans.degraded:
            m.degraded += 1
            m.degrade_reasons[ans.degrade_reason] += 1
        if ans.deadline_missed:
            m.deadline_misses += 1
            m.max_overrun_s = max(m.max_overrun_s, ans.overrun_s)

    def _expire(self, now: float) -> list[Answer]:
        """Requests whose deadline passed while queued answer empty-degraded
        immediately — the overrun is bounded by one batch interval because
        this runs between batches."""
        dead = [r for r in self._queue
                if r.deadline is not None and r.deadline <= now]
        if not dead:
            return []
        gone = {r.rid for r in dead}
        self._queue = deque(r for r in self._queue if r.rid not in gone)
        answers = []
        for r in dead:
            ans = Answer(
                rid=r.rid, kind=r.kind,
                result=0 if r.kind == "count" else [],
                degraded=True, degrade_reason="deadline:empty",
                deadline_missed=True, overrun_s=float(now - r.deadline),
                latency_s=float(now - r.submitted_at), path="empty",
            )
            self._account(ans)
            answers.append(ans)
        return answers

    # -- driving -------------------------------------------------------------

    def step(self) -> list[Answer]:
        """Expire overdue queued requests, then cut and execute one batch."""
        answers = self._expire(self._clock())
        batch = self._cut_batch(self._clock())
        if batch:
            answers.extend(self._execute_batch(batch))
        return answers

    def run_until_idle(self) -> dict[int, Answer]:
        out: dict[int, Answer] = {}
        while self._queue:
            for ans in self.step():
                out[ans.rid] = ans
        return out

    def serve(self, requests, *, deadline_s: float | None = None) -> list[Answer]:
        """Convenience: submit ``(kind, payload)`` pairs, drain the queue,
        return answers in submission order."""
        rids = [self.submit(kind, payload, deadline_s=deadline_s)
                for kind, payload in requests]
        answers = self.run_until_idle()
        return [answers[rid] for rid in rids]

    def warmup(self, kinds=KINDS, batch_sizes=(1,)) -> dict:
        """Pre-compile the (kind, bucket) programs outside any deadline.

        Returns per-bucket compile seconds (also in ``metrics.compile_s``);
        serving traffic on a warm bucket then only sees steady-state
        latency."""
        probe = np.asarray([1], np.int32)
        for kind in kinds:
            for b in batch_sizes:
                payload = [probe] if kind == "tfidf" else probe
                for _ in range(b):
                    self.submit(kind, payload, deadline_s=1e9)
                self.run_until_idle()
        return dict(self.metrics.compile_s)
