"""Index integrity validation — reject a corrupted index before it serves.

A serving process that loads (or is handed) corrupted index pytrees does
not crash: succinct structures are all gathers and prefix sums, so a
flipped word or a truncated offset array silently yields *wrong answers*.
This module checks the structural invariants the query algorithms assume,
at build/load time, and raises :class:`repro.errors.IndexIntegrityError`
on the first violation:

* bitvectors: rank metadata (``ones_prefix``) recomputed exactly from the
  words; padding bits beyond ``n`` must be zero; sparse positions strictly
  increasing and in range; RLE runs tile ``[0, n)`` with a consistent ones
  prefix;
* wavelet matrices: per-level zero counts consistent with the level
  popcounts, and ``sym_starts`` re-derived by the full per-symbol descent
  (the pair-descent rank and the fused backward-search kernel both lean on
  it — a wrong entry mis-ranks every query);
* CSA: the C array monotone with ``C[0] = 0`` and ``C[1] = d`` (one
  terminator per document); a device spot check that the wavelet matrix's
  symbol histogram matches the C array deltas; SA samples in range and
  aligned with the sampled-positions bitvector;
* ILCP: run boundaries strictly increasing and tiling ``[0, n)``; maximal
  runs (adjacent head values differ); the value-sorted cumulative lengths
  ending at ``n``; the RMQ table built over exactly the run-head values;
* PDL: leaf tiling of the SA; monotone set offsets ending at ``|A|``;
  grammar symbols in ``[0, d + nrules]``; strictly increasing top-k
  frequency cumulatives;
* Sada: the unary H' encoding consistent with the variant's filter
  bitvectors and slot count.

``fingerprint_service`` additionally checksums every array leaf (CRC32),
so a load path can detect bit-level corruption that happens to satisfy the
structural invariants; ``RetrievalService.build(validate=True)`` (the
default) runs the full validation once and stores the fingerprints.
"""

from __future__ import annotations

import zlib

import numpy as np
import jax

from repro.errors import IndexIntegrityError
from repro.succinct.bitvector import (
    PlainBitvector,
    RLEBitvector,
    SparseBitvector,
)
from repro.succinct.wavelet import WaveletMatrix


def _req(cond: bool, name: str, msg: str) -> None:
    if not cond:
        raise IndexIntegrityError(f"{name}: {msg}")


def _np(x) -> np.ndarray:
    return np.asarray(x)


def _word_popcounts(words: np.ndarray) -> np.ndarray:
    flat = np.ascontiguousarray(words, dtype=np.uint32)
    return np.unpackbits(flat.view(np.uint8)).reshape(*flat.shape, 32).sum(
        axis=-1, dtype=np.int64
    )


def _unpacked_bits(words: np.ndarray) -> np.ndarray:
    """Word array -> flat 0/1 bit array, LSB-first within each 32-bit word
    (the pack_bits_np layout)."""
    flat = np.ascontiguousarray(words, dtype=np.uint32)
    le = flat.view(np.uint8)
    if flat.dtype.byteorder == ">" or (flat.dtype.byteorder == "=" and
                                       np.little_endian is False):
        le = le.reshape(-1, 4)[:, ::-1].ravel()
    return np.unpackbits(le, bitorder="little")


# ---------------------------------------------------------------------------
# Bitvectors
# ---------------------------------------------------------------------------


def validate_plain_bitvector(bv: PlainBitvector, name: str) -> None:
    words, ones = _np(bv.words), _np(bv.ones_prefix)
    _req(words.shape == ones.shape, name, "words/ones_prefix shape mismatch")
    _req(words.shape[0] * 32 >= bv.n + 32, name, "missing pad word")
    pops = _word_popcounts(words)
    want = np.zeros_like(ones)
    want[1:] = np.cumsum(pops[:-1])
    _req(np.array_equal(ones, want), name, "ones_prefix != popcount prefix")
    _req(int(ones[-1]) == bv.m, name, f"m={bv.m} != total ones {int(ones[-1])}")
    # padding bits beyond n must be zero (rank(n) reads them masked, but
    # select scans whole words)
    _req(not _unpacked_bits(words)[bv.n:].any(), name, "set bits beyond n")
    zeros = _np(bv.zeros_prefix)
    starts = np.minimum(np.arange(len(words), dtype=np.int64) * 32, bv.n)
    _req(np.array_equal(zeros, starts - ones), name,
         "zeros_prefix inconsistent with ones_prefix")


def validate_sparse_bitvector(bv: SparseBitvector, name: str) -> None:
    pos = _np(bv.pos)
    _req(0 <= bv.m <= bv.n, name, f"m={bv.m} out of range for n={bv.n}")
    if bv.m == 0:
        return  # pos holds the [n] placeholder
    _req(pos.shape[0] == bv.m, name, f"pos has {pos.shape[0]} entries, m={bv.m}")
    _req((np.diff(pos) > 0).all() if bv.m > 1 else True, name,
         "positions not strictly increasing")
    _req(0 <= int(pos[0]) and int(pos[-1]) < bv.n, name, "position out of [0, n)")


def validate_rle_bitvector(bv: RLEBitvector, name: str) -> None:
    rs, ones = _np(bv.run_starts), _np(bv.ones_prefix)
    _req(rs.shape[0] == bv.nruns + 1 == ones.shape[0], name,
         "run_starts/ones_prefix length mismatch")
    _req(int(rs[0]) == 0 and int(rs[-1]) == bv.n, name,
         "runs do not tile [0, n)")
    _req((np.diff(rs) > 0).all() if bv.nruns else True, name,
         "empty or reordered run")
    lens = np.diff(rs)
    vals = np.bitwise_xor(np.arange(bv.nruns) & 1, bv.first_bit)
    want = np.concatenate([[0], np.cumsum(lens * vals)])
    _req(np.array_equal(ones, want), name, "ones_prefix != run decode")
    _req(int(want[-1]) == bv.m, name, f"m={bv.m} != decoded ones {int(want[-1])}")


def _validate_any_bitvector(bv, name: str) -> None:
    if isinstance(bv, PlainBitvector):
        validate_plain_bitvector(bv, name)
    elif isinstance(bv, SparseBitvector):
        validate_sparse_bitvector(bv, name)
    elif isinstance(bv, RLEBitvector):
        validate_rle_bitvector(bv, name)
    else:  # pragma: no cover - new variants must be wired in here
        raise IndexIntegrityError(f"{name}: unknown bitvector type {type(bv)}")


# ---------------------------------------------------------------------------
# Wavelet matrix
# ---------------------------------------------------------------------------


def _wm_host_rank1(words, prefix, lvl: int, pos: np.ndarray) -> np.ndarray:
    w = pos >> 5
    mask = (np.uint32(1) << (pos & 31).astype(np.uint32)) - np.uint32(1)
    masked = words[lvl][w] & mask
    pc = np.array([int(v).bit_count() for v in masked], dtype=np.int64)
    return prefix[lvl][w].astype(np.int64) + pc


def validate_wavelet(wm: WaveletMatrix, name: str) -> None:
    words, prefix, zc = _np(wm.words), _np(wm.ones_prefix), _np(wm.zcount)
    _req(words.shape == prefix.shape and words.shape[0] == wm.levels, name,
         "level shape mismatch")
    _req(zc.shape[0] == wm.levels, name, "zcount length != levels")
    pops = _word_popcounts(words)
    want = np.zeros_like(prefix)
    want[:, 1:] = np.cumsum(pops[:, :-1], axis=1)
    _req(np.array_equal(prefix, want), name, "ones_prefix != popcount prefix")
    for lvl in range(wm.levels):
        _req(not _unpacked_bits(words[lvl])[wm.n:].any(), name,
             f"level {lvl}: set bits beyond n")
        total = int(prefix[lvl, -1])
        _req(int(zc[lvl]) == wm.n - total, name,
             f"level {lvl}: zcount {int(zc[lvl])} != n - ones {wm.n - total}")
    # sym_starts: re-derive by the exact per-symbol descent the builder runs
    syms = np.arange(wm.sigma, dtype=np.int64)
    s = np.zeros(wm.sigma, dtype=np.int64)
    for lvl in range(wm.levels):
        bit = (syms >> (wm.levels - 1 - lvl)) & 1
        r1 = _wm_host_rank1(words, prefix, lvl, s)
        s = np.where(bit == 0, s - r1, zc[lvl] + r1)
    _req(np.array_equal(_np(wm.sym_starts), s.astype(np.int32)), name,
         "sym_starts != descent of position 0 (pair-descent rank would "
         "mis-rank every query)")


def wm_symbol_histogram(wm: WaveletMatrix) -> np.ndarray:
    """Per-symbol occurrence counts decoded from the wavelet matrix alone:
    rank_c(n) = descend(n following c) - sym_starts[c], computed on host
    for every symbol at once (the same descent the builder runs for
    position 0)."""
    words, prefix, zc = _np(wm.words), _np(wm.ones_prefix), _np(wm.zcount)
    syms = np.arange(wm.sigma, dtype=np.int64)
    e = np.full(wm.sigma, wm.n, dtype=np.int64)
    for lvl in range(wm.levels):
        bit = (syms >> (wm.levels - 1 - lvl)) & 1
        r1 = _wm_host_rank1(words, prefix, lvl, e)
        e = np.where(bit == 0, e - r1, zc[lvl] + r1)
    return (e - _np(wm.sym_starts)).astype(np.int64)


# ---------------------------------------------------------------------------
# Index structures
# ---------------------------------------------------------------------------


def validate_csa(csa, name: str = "csa") -> None:
    counts = _np(csa.counts)
    _req(counts.shape[0] == csa.sigma + 1, name, "C array length != sigma + 1")
    _req(int(counts[0]) == 0, name, "C[0] != 0")
    _req((np.diff(counts) >= 0).all(), name, "C array not monotone")
    _req(int(counts[-1]) <= csa.n, name, "C[sigma] > n")
    _req(int(counts[1]) == csa.d, name,
         "C[1] != d (one terminator per document)")
    validate_wavelet(csa.wm, f"{name}.wm")
    _req(csa.wm.n == csa.n and csa.wm.sigma == csa.sigma, name,
         "wavelet matrix n/sigma mismatch")
    # cross-structure check: the BWT's symbol histogram decoded from the
    # wavelet matrix must equal the C array deltas exactly
    hist = wm_symbol_histogram(csa.wm)
    _req(np.array_equal(hist, np.diff(counts).astype(np.int64)), name,
         "BWT symbol histogram != C array deltas")
    validate_sparse_bitvector(csa.sampled, f"{name}.sampled")
    validate_sparse_bitvector(csa.doc_bv, f"{name}.doc_bv")
    _req(csa.doc_bv.m == csa.d, name, "doc_bv ones != d")
    samples = _np(csa.samples)
    _req(samples.shape[0] == csa.sampled.m, name,
         "samples length != sampled positions")
    _req(samples.size == 0 or (0 <= samples.min() and samples.max() < csa.n),
         name, "SA sample out of [0, n)")


def validate_ilcp(ilcp, name: str = "ilcp") -> None:
    rho = ilcp.nruns
    bounds, vilcp, clens = _np(ilcp.run_starts), _np(ilcp.vilcp), _np(ilcp.clens)
    _req(vilcp.shape[0] == rho, name, "vilcp length != nruns")
    _req(bounds.shape[0] == rho + 1, name, "run bounds length != nruns + 1")
    _req(int(bounds[0]) == 0 and int(bounds[-1]) == ilcp.n, name,
         "runs do not tile [0, n)")
    _req((np.diff(bounds) > 0).all(), name, "empty or reordered run")
    _req(rho < 2 or bool((vilcp[1:] != vilcp[:-1]).all()), name,
         "runs not maximal (adjacent runs share a head value)")
    _req(vilcp.size == 0 or (0 <= vilcp.min() and vilcp.max() == ilcp.max_value),
         name, "vilcp values out of [0, max_value]")
    _req(clens.shape[0] == rho + 1, name, "clens length != nruns + 1")
    _req(int(clens[0]) == 0 and int(clens[-1]) == ilcp.n, name,
         "value-sorted run lengths do not sum to n")
    _req((np.diff(clens) > 0).all(), name, "clens not strictly increasing")
    vro = _np(ilcp.value_run_offset)
    _req(vro.shape[0] == ilcp.max_value + 2, name,
         "value_run_offset length != max_value + 2")
    _req(int(vro[0]) == 0 and int(vro[-1]) == rho, name,
         "value_run_offset does not cover all runs")
    _req((np.diff(vro) >= 0).all(), name, "value_run_offset not monotone")
    validate_sparse_bitvector(ilcp.L, f"{name}.L")
    _req(ilcp.L.m == rho and ilcp.L.n == ilcp.n, name,
         "L bitvector shape mismatch")
    _req(np.array_equal(_np(ilcp.L.pos), bounds[:-1]), name,
         "L ones != run starts")
    validate_wavelet(ilcp.wm, f"{name}.wm")
    _req(ilcp.wm.n == rho, name, "wavelet matrix not over the run heads")
    _req(np.array_equal(_np(ilcp.rmq.values), vilcp), name,
         "RMQ not built over the run-head values")


def validate_pdl(pdl, name: str = "pdl") -> None:
    L, I, d, nR = pdl.L, pdl.I, pdl.d, pdl.nrules
    leaf = _np(pdl.leaf_starts)
    _req(leaf.shape[0] == L + 1, name, "leaf_starts length != L + 1")
    _req(int(leaf[0]) == 0 and int(leaf[-1]) == pdl.n, name,
         "leaves do not tile the SA")
    _req((np.diff(leaf) > 0).all(), name, "empty or reordered leaf")
    soff, A = _np(pdl.set_off), _np(pdl.A)
    _req(soff.shape[0] == L + I + 1, name, "set_off length != L + I + 1")
    _req(int(soff[0]) == 0 and int(soff[-1]) == A.shape[0], name,
         "set_off does not cover A")
    _req((np.diff(soff) >= 0).all(), name, "set_off not monotone")
    _req(A.size == 0 or (0 <= A.min() and A.max() <= d + nR), name,
         "grammar symbol out of [0, d + nrules]")
    for fld in ("rule_left", "rule_right"):
        r = _np(getattr(pdl, fld))
        _req(r.size == 0 or (0 <= r.min() and r.max() <= d + nR), name,
             f"{fld} symbol out of range")
    base = _np(pdl.doc_base)
    _req(base.shape[0] == L + I + 1, name, "doc_base length != L + I + 1")
    _req(int(base[0]) == 0 and (np.diff(base) >= 0).all(), name,
         "doc_base not a prefix sum")
    nl = _np(pdl.next_leaf)
    _req(nl.size == 0 or (0 <= nl.min() and nl.max() <= L), name,
         "next_leaf out of [0, L]")
    par = _np(pdl.parent_of)
    _req(par.size == 0 or (-1 <= par.min() and par.max() < L + I), name,
         "parent_of out of range")
    if pdl.has_freqs:
        fv, gc = _np(pdl.freq_vals), _np(pdl.freq_gcum)
        _req(fv.shape == gc.shape, name, "freq_vals/freq_gcum shape mismatch")
        _req(fv.size == 0 or fv.min() >= 0, name, "negative frequency value")
        _req(gc.size == 0 or (int(gc[0]) > 0 and (np.diff(gc) > 0).all()),
             name, "freq_gcum not strictly increasing")


def validate_sada(sada, name: str = "sada") -> None:
    _req(sada.num_slots == max(0, sada.n - 1), name,
         "num_slots != n - 1")
    _validate_any_bitvector(sada.hp, f"{name}.hp")
    validate_sparse_bitvector(sada.fs, f"{name}.fs")
    validate_sparse_bitvector(sada.f1, f"{name}.f1")
    # the unary H' code has one 1 per encoded slot; which slots are encoded
    # depends on the variant
    if sada.variant in ("plain", "rle", "sparse"):
        _req(sada.hp.m == sada.num_slots, name,
             "unary H' does not encode every slot")
    else:  # filter_plain / sparse_sparse: H' restricted to filtered slots
        _req(sada.hp.m == sada.fs.m, name,
             "unary H' ones != filtered slot count")


# ---------------------------------------------------------------------------
# Whole-service validation + checksums
# ---------------------------------------------------------------------------


def checksum_pytree(tree) -> int:
    """Order-sensitive CRC32 over every array leaf (bit-level identity)."""
    crc = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        crc = zlib.crc32(np.ascontiguousarray(_np(leaf)).tobytes(), crc)
    return crc


def fingerprint_service(svc) -> dict:
    """Per-structure checksums, for load-time bit-corruption detection."""
    return {
        comp: checksum_pytree(getattr(svc, comp))
        for comp in ("csa", "ilcp", "pdl_list", "pdl_topk", "sada", "da")
    }


def verify_fingerprints(svc, expected: dict) -> None:
    got = fingerprint_service(svc)
    bad = sorted(k for k in expected if got.get(k) != expected[k])
    if bad:
        raise IndexIntegrityError(
            f"index checksum mismatch in: {', '.join(bad)} "
            "(bit-level corruption; structural invariants may still hold)"
        )


def validate_service(svc) -> dict:
    """Run every structural validator over a RetrievalService's indexes.

    Raises IndexIntegrityError on the first violated invariant; returns
    the service fingerprints when everything holds."""
    validate_csa(svc.csa)
    validate_ilcp(svc.ilcp)
    validate_pdl(svc.pdl_list, "pdl_list")
    validate_pdl(svc.pdl_topk, "pdl_topk")
    validate_sada(svc.sada)
    da = _np(svc.da)
    _req(da.size == 0 or (0 <= da.min() and da.max() < svc.coll.d), "da",
         "document-array entry out of [0, d)")
    return fingerprint_service(svc)


def validate_sharded_service(svc) -> dict:
    """Validate a docs-mesh ShardedRetrievalService: every per-shard index
    stack passes the full structural validation, plus the cross-shard
    partition invariants the merge algebra assumes.  Returns fingerprints
    keyed ``shard{S}:{structure}``."""
    S = svc.n_shards
    _req(S >= 1, "shards", "no shards")
    _req(len(svc.doc_bases) == S, "shards", "doc_bases length != n_shards")
    _req(int(svc.doc_bases[0]) == 0, "shards", "first shard not at doc 0")
    _req((np.diff(np.asarray(svc.doc_bases)) > 0).all() if S > 1 else True,
         "shards", "doc_bases not strictly increasing")
    total_d = 0
    total_n = 0
    fps = {}
    for s, shard in enumerate(svc.shards):
        dlo, dhi = svc.shard_doc_range(s)
        _req(shard.coll.d == dhi - dlo, f"shard{s}",
             "shard document count != owned range")
        _req(shard.coll.d >= 1, f"shard{s}", "empty shard (zero documents)")
        _req(shard.coll.sigma == svc.coll.sigma, f"shard{s}",
             "shard sigma != global sigma (wavelet levels would diverge)")
        # the shard's text must be the exact slice it claims to own
        base = int(svc.coll.doc_starts[dlo])
        _req(np.array_equal(
            _np(shard.coll.text),
            _np(svc.coll.text)[base:base + shard.coll.n]), f"shard{s}",
            "shard text != collection slice")
        for fp_name, fp in validate_service(shard).items():
            fps[f"shard{s}:{fp_name}"] = fp
        total_d += shard.coll.d
        total_n += shard.coll.n
    _req(total_d == svc.coll.d, "shards",
         f"shard documents sum to {total_d}, collection has {svc.coll.d}")
    _req(total_n == svc.coll.n, "shards",
         f"shard texts sum to {total_n} symbols, collection has {svc.coll.n}")
    return fps
