"""Batched document-retrieval serving — the paper's contribution deployed
as the framework's retrieval layer.

One service object owns the full index stack over a document collection:

    CSA (RLCSA-accounted FM-index)        pattern -> SA range
    ILCP                                  listing (Sada-I) + counting
    PDL (+F)                              listing + top-k with frequencies
    Sadakane (compressed variants)        document counting
    TF-IDF                                ranked multi-term AND/OR

and exposes *batched, jitted* endpoints.  Queries arrive as padded pattern
batches (the dense layout accelerators want); every endpoint is a single
compiled program per (batch-shape, k) signature.

The dispatch policy implements the paper's own recommendation (Section
6.2.2): compute df cheaply first (Sada-S), compare with occ = hi - lo, and
route to Brute-L when occ/df is small or the range is tiny, to the
ILCP/PDL machinery otherwise.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.csa import build_csa, csa_search_batch
from repro.core.ilcp import build_ilcp, ilcp_count_docs_batch, ilcp_list_docs_da
from repro.core.listing import brute_list_csa, brute_topk
from repro.core.pdl import build_pdl, pdl_list_docs, pdl_topk
from repro.core.sada import build_sada, sada_count_batch
from repro.core.suffix import Collection, build_suffix_data
from repro.core.tfidf import tfidf_topk_batch
from repro.data.collections import pad_patterns


@dataclasses.dataclass
class RetrievalService:
    coll: Collection
    csa: object
    ilcp: object
    pdl_list: object
    pdl_topk: object
    sada: object
    da: object
    occ_df_threshold: float = 4.0     # paper: brute wins when occ/df < ~4

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls, coll: Collection, block_size: int = 64, beta: float = 16.0,
        sada_variant: str = "sparse", sample_rate: int = 16,
    ):
        data = build_suffix_data(coll)
        return cls(
            coll=coll,
            csa=build_csa(data, sample_rate=sample_rate),
            ilcp=build_ilcp(data),
            pdl_list=build_pdl(data, block_size=block_size, beta=beta, mode="list"),
            pdl_topk=build_pdl(data, block_size=block_size, beta=None, mode="topk"),
            sada=build_sada(data, sada_variant),
            da=jnp.asarray(data.da),
        )

    # -- endpoints ------------------------------------------------------------

    def ranges(self, patterns):
        pats, lens = pad_patterns(patterns)
        lo, hi = csa_search_batch(self.csa, jnp.asarray(pats), jnp.asarray(lens))
        return np.asarray(lo), np.asarray(hi), np.asarray(lens)

    def count(self, patterns):
        """df per pattern (Sada variant; ILCP counting cross-checks)."""
        lo, hi, lens = self.ranges(patterns)
        return np.asarray(sada_count_batch(self.sada, jnp.asarray(lo), jnp.asarray(hi)))

    def count_ilcp(self, patterns):
        lo, hi, lens = self.ranges(patterns)
        return np.asarray(
            ilcp_count_docs_batch(
                self.ilcp, jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(lens)
            )
        )

    def list_docs(self, patterns, max_df: int = 256, engine: str = "auto",
                  max_buf: int = 4096):
        """Document listing with the paper's df/occ dispatch policy."""
        lo, hi, lens = self.ranges(patterns)
        dfs = np.asarray(sada_count_batch(self.sada, jnp.asarray(lo), jnp.asarray(hi)))
        out = []
        for qi in range(len(lo)):
            l, h = int(lo[qi]), int(hi[qi])
            if l >= h:
                out.append([])
                continue
            occ = h - l
            df = max(int(dfs[qi]), 1)
            eng = engine
            if engine == "auto":
                eng = "brute" if occ / df < self.occ_df_threshold else "pdl"
            if eng == "brute":
                docs, cnt, _ = brute_list_csa(
                    self.csa, l, h, max_occ=min(occ, max_buf), max_df=max_df
                )
            elif eng == "ilcp":
                docs, cnt = ilcp_list_docs_da(self.ilcp, self.da, l, h, max_df)
            else:
                docs, cnt = pdl_list_docs(
                    self.pdl_list, self.csa, l, h, max_df, max_buf=max_buf
                )
            out.append(sorted(np.asarray(docs)[: int(cnt)].tolist()))
        return out

    def topk(self, patterns, k: int = 10, max_buf: int = 4096):
        lo, hi, lens = self.ranges(patterns)
        out = []
        for qi in range(len(lo)):
            l, h = int(lo[qi]), int(hi[qi])
            if l >= h:
                out.append([])
                continue
            docs, tfs = pdl_topk(self.pdl_topk, self.csa, l, h, k, max_buf=max_buf)
            out.append(
                [(int(d), int(t)) for d, t in zip(np.asarray(docs), np.asarray(tfs))
                 if d >= 0]
            )
        return out

    def tfidf(self, queries, k: int = 10, conjunctive: bool = False,
              max_terms: int = 4, max_buf: int = 2048):
        """queries: list of term-pattern lists.  Returns ranked (doc, score)."""
        Q = len(queries)
        ranges = np.zeros((Q, max_terms, 2), np.int32)
        valid = np.zeros((Q, max_terms), bool)
        for qi, terms in enumerate(queries):
            lo, hi, _ = self.ranges(terms[:max_terms])
            for ti in range(len(lo)):
                ranges[qi, ti] = (lo[ti], hi[ti])
                valid[qi, ti] = True
        docs, scores = tfidf_topk_batch(
            self.pdl_topk, self.csa, self.sada, ranges, valid, k, conjunctive,
            max_buf=max_buf,
        )
        out = []
        for qi in range(Q):
            out.append(
                [(int(d), float(s)) for d, s in zip(np.asarray(docs[qi]),
                                                    np.asarray(scores[qi])) if d >= 0]
            )
        return out

    # -- introspection --------------------------------------------------------

    def space_report(self) -> dict:
        """Bits-per-character accounting in the paper's units."""
        n = self.coll.n
        return {
            "n": n,
            "d": self.coll.d,
            "csa_rlcsa_bpc": self.csa.modeled_bits_rlcsa() / n,
            "ilcp_listing_bpc": self.ilcp.modeled_bits_listing() / n,
            "ilcp_counting_bpc": self.ilcp.modeled_bits_counting() / n,
            "pdl_list_bpc": self.pdl_list.modeled_bits() / n,
            "pdl_topk_bpc": self.pdl_topk.modeled_bits() / n,
            "sada_bpc": self.sada.modeled_bits() / n,
            "bwt_runs": self.csa.bwt_runs,
            "ilcp_runs": self.ilcp.nruns,
        }
