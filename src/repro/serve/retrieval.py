"""Batched document-retrieval serving — the paper's contribution deployed
as the framework's retrieval layer.

One service object owns the full index stack over a document collection:

    CSA (RLCSA-accounted FM-index)        pattern -> SA range
    ILCP                                  listing (Sada-I) + counting
    PDL (+F)                              listing + top-k with frequencies
    Sadakane (compressed variants)        document counting
    TF-IDF                                ranked multi-term AND/OR

Execution architecture — a three-stage on-device engine:

1. **Planner** (repro.serve.planner): one fused pass over the padded
   pattern batch computes (lo, hi) ranges, df (Sada), occ, and a per-query
   engine assignment as an int32 array.  This is the paper's Section 6.2.2
   dispatch policy (Brute-L when occ/df is small, PDL otherwise) with the
   branching moved from Python onto the device.  The range search runs as
   ONE fused Pallas backward-search launch per batch on TPU
   (repro.kernels.backward_search; backend auto-detected) and as the
   pair-descent XLA program elsewhere — both bit-identical to the
   reference.  Planner occ stats also size the Brute-L locate window per
   compile bucket (dispatch-aware, grow-only powers of two), replacing the
   static max_buf window.
2. **Masked batch executors** (repro.core.{listing,ilcp,pdl,tfidf}):
   vmapped fixed-shape ``*_batch`` entry points.  Every engine runs over
   the full batch with the queries not assigned to it collapsed to empty
   ranges; outputs are padded (B, max_df) arrays with -1 sentinels, and the
   final result is a ``jnp.where`` select by engine id.
3. **Shape-bucketed compile cache** (this module): ``count``,
   ``list_docs``, ``topk``, and ``tfidf`` each lower planner + executors to
   ONE compiled program per (batch-bucket, length-bucket, k, max_df, ...)
   signature.  Batch sizes round up to powers of two and pattern lengths to
   multiples of 8, so recompilation is bounded regardless of traffic; the
   AOT executables are compiled exactly once per bucket (``compile_counts``
   exposes the tally for tests and monitoring).

Engine mode is a *traced* input (an int code, -1 = auto), so switching
between auto/brute/ilcp/pdl reuses the same executable.  The original
per-query host loop survives as ``engine="reference"`` (optionally
``"reference:brute"`` etc. to force a sub-engine) and is the parity oracle
for the batched path — results are bit-identical by construction because
both sides run the same per-query programs.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import IDX
from repro.core.csa import build_csa
from repro.core.ilcp import (
    build_ilcp,
    ilcp_count_docs_batch,
    ilcp_list_docs_da,
    ilcp_list_docs_da_planned,
)
from repro.core.listing import (
    brute_list_csa,
    brute_list_csa_batch,
    brute_topk,
    brute_topk_batch,
)
from repro.core.pdl import (
    build_pdl,
    pdl_list_docs,
    pdl_list_docs_batch,
    pdl_topk,
    pdl_topk_batch,
)
from repro.core.sada import build_sada, sada_count_batch
from repro.core.suffix import Collection, build_suffix_data
from repro.core.tfidf import term_ranges_batch, tfidf_topk_batch
from repro.data.collections import normalize_patterns, pad_patterns
from repro.serve import faults
from repro.serve.planner import (
    ENGINE_BRUTE,
    ENGINE_CODES,
    ENGINE_EMPTY,
    ENGINE_ILCP,
    ENGINE_PDL,
    masked_ranges,
    plan_queries,
)

_BIG = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------


def _bucket_batch(b: int) -> int:
    """Round a batch size up to the next power of two (>= 1)."""
    return 1 if b <= 1 else 1 << (b - 1).bit_length()


def _bucket_len(m: int) -> int:
    """Round a pattern length up to a multiple of 8 (>= 8)."""
    return max(8, -(-m // 8) * 8)


#: smallest dispatch-aware Brute-L window; windows grow in powers of two up
#: to the endpoint's ``max_buf``, so each bucket recompiles at most
#: lg(max_buf / floor) times as traffic reveals larger brute ranges.
BRUTE_WINDOW_FLOOR = 32

#: largest servable pattern-length bucket.  Patterns longer than this never
#: reach the device: ``normalize_patterns`` collapses them to empty queries
#: (empty results), so one absurd request cannot force a giant compile.
MAX_PATTERN_LEN = 4096


def _pow2_ceil(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


# ---------------------------------------------------------------------------
# Fused programs (pure functions of the index pytrees; compiled per bucket)
# ---------------------------------------------------------------------------


def _sorted_rows(docs):
    """Canonical listing layout: ascending doc ids, -1 padding at the end."""
    keys = jnp.where(docs < 0, _BIG, docs)
    s = jnp.sort(keys, axis=1)
    return jnp.where(s == _BIG, -1, s).astype(IDX)


def _plan_program(use_kernel, csa, sada, patterns, lengths, threshold, forced):
    return plan_queries(
        csa, sada, patterns, lengths, threshold, forced,
        use_kernel=use_kernel,
    )


def _list_program(
    max_df, brute_win, max_buf, use_kernel, use_list_kernel,
    csa, ilcp, pdl, da, sada, patterns, lengths, threshold, forced,
):
    """list_docs as one program: plan, run all engines masked, select.

    ``brute_win`` is the Brute-L locate window — sized per compile bucket
    from planner occ stats (dispatch-aware), not the static ``max_buf``.
    ``use_list_kernel`` selects the ILCP executor's backend: the fused
    Pallas listing kernel (one launch — the program's second, after the
    planner's backward search) or the XLA vmap'd while_loop.
    """
    plan = plan_queries(
        csa, sada, patterns, lengths, threshold, forced,
        use_kernel=use_kernel,
    )
    bl, bh = masked_ranges(plan, ENGINE_BRUTE)
    docs_b, cnt_b, _ = brute_list_csa_batch(csa, bl, bh, brute_win, max_df)
    il, ih = masked_ranges(plan, ENGINE_ILCP)
    docs_i, cnt_i = ilcp_list_docs_da_planned(
        ilcp, da, il, ih, max_df, use_kernel=use_list_kernel
    )
    pl, ph = masked_ranges(plan, ENGINE_PDL)
    docs_p, cnt_p = pdl_list_docs_batch(pdl, csa, pl, ph, max_df, max_buf)

    eng = plan.engine[:, None]
    docs = jnp.where(
        eng == ENGINE_BRUTE, docs_b,
        jnp.where(eng == ENGINE_ILCP, docs_i, docs_p),
    )
    docs = jnp.where(eng == ENGINE_EMPTY, -1, docs)
    cnt = jnp.where(
        plan.engine == ENGINE_BRUTE, cnt_b,
        jnp.where(plan.engine == ENGINE_ILCP, cnt_i, cnt_p),
    )
    cnt = jnp.where(plan.engine == ENGINE_EMPTY, 0, cnt).astype(IDX)
    return _sorted_rows(docs), cnt, plan


def _topk_program(
    k, max_df, brute_win, max_buf, use_kernel,
    csa, pdl_t, sada, patterns, lengths, threshold, forced,
):
    """top-k as one program.  Brute-assigned queries take the sorted-window
    path (exact tf within the occ window); ILCP has no top-k structure, so
    its queries ride the PDL lists, as in the paper's Section 6.3 lineup."""
    plan = plan_queries(
        csa, sada, patterns, lengths, threshold, forced,
        use_kernel=use_kernel,
    )
    bl, bh = masked_ranges(plan, ENGINE_BRUTE)
    d_b, c_b, f_b = brute_list_csa_batch(csa, bl, bh, brute_win, max_df)
    tb_docs, tb_tf = brute_topk_batch(d_b, c_b, f_b, k)

    use_pdl = (plan.engine == ENGINE_PDL) | (plan.engine == ENGINE_ILCP)
    pl = jnp.where(use_pdl, plan.lo, 0)
    ph = jnp.where(use_pdl, plan.hi, 0)
    tp_docs, tp_tf = pdl_topk_batch(pdl_t, csa, pl, ph, k, max_buf)

    is_brute = (plan.engine == ENGINE_BRUTE)[:, None]
    docs = jnp.where(is_brute, tb_docs, tp_docs)
    tfs = jnp.where(is_brute, tb_tf, tp_tf)
    empty = (plan.engine == ENGINE_EMPTY)[:, None]
    return jnp.where(empty, -1, docs), jnp.where(empty, 0, tfs), plan


def _tfidf_program(
    k, conjunctive, max_buf, use_kernel,
    csa, pdl_t, sada, patterns, lengths,
):
    """Multi-term ranked query as one program: fused term range search +
    batched ranked-AND/OR scoring.  ``use_kernel`` selects the same
    backward-search backend as the planner (True = one fused Pallas launch
    for the whole [Q*T] term batch)."""
    ranges, valid = term_ranges_batch(csa, patterns, lengths, use_kernel=use_kernel)
    return tfidf_topk_batch(
        pdl_t, csa, sada, ranges, valid, k, conjunctive, max_buf=max_buf
    )


@dataclasses.dataclass
class RetrievalService:
    coll: Collection
    csa: object
    ilcp: object
    pdl_list: object
    pdl_topk: object
    sada: object
    da: object
    occ_df_threshold: float = 4.0     # paper: brute wins when occ/df < ~4
    use_search_kernel: bool = False   # fused Pallas backward search (TPU path)
    use_list_kernel: bool = False     # fused Pallas ILCP listing (TPU path)
    brute_window: int | None = None   # None = size per bucket from occ stats
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)
    _brute_windows: dict = dataclasses.field(default_factory=dict, repr=False)
    compile_counts: dict = dataclasses.field(default_factory=dict, repr=False)
    #: per-structure CRC32s recorded by build-time validation (``repro.
    #: serve.validate``); a load path compares them via verify_fingerprints
    fingerprints: dict = dataclasses.field(default_factory=dict, repr=False)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls, coll: Collection, block_size: int = 64, beta: float = 16.0,
        sada_variant: str = "sparse", sample_rate: int = 16,
        use_search_kernel: bool | None = None,
        use_list_kernel: bool | None = None,
        brute_window: int | None = None,
        validate: bool = True,
        mesh=None,
    ):
        if mesh is not None:
            # docs-axis sharded service: contiguous document shards, each
            # with its own index stack, merged on-device (docs/SHARDING.md)
            from repro.serve.sharded import ShardedRetrievalService

            return ShardedRetrievalService.build(
                coll, mesh, block_size=block_size, beta=beta,
                sada_variant=sada_variant, sample_rate=sample_rate,
                use_search_kernel=use_search_kernel,
                use_list_kernel=use_list_kernel,
                brute_window=brute_window, validate=validate,
            )
        data = build_suffix_data(coll)
        if use_search_kernel is None:
            # backend auto-detection: the fused backward-search kernel is
            # the default on TPU; elsewhere the XLA pair descent wins
            use_search_kernel = jax.default_backend() == "tpu"
        if use_list_kernel is None:
            # same auto-detection for the fused ILCP listing kernel
            use_list_kernel = jax.default_backend() == "tpu"
        svc = cls(
            coll=coll,
            csa=build_csa(data, sample_rate=sample_rate),
            ilcp=build_ilcp(data),
            pdl_list=build_pdl(data, block_size=block_size, beta=beta, mode="list"),
            pdl_topk=build_pdl(data, block_size=block_size, beta=None, mode="topk"),
            sada=build_sada(data, sada_variant),
            da=jnp.asarray(data.da),
            use_search_kernel=use_search_kernel,
            use_list_kernel=use_list_kernel,
            brute_window=brute_window,
        )
        if validate:
            # structural invariants + checksums: a corrupted index is
            # rejected here, before it can serve wrong answers
            from repro.serve.validate import validate_service

            svc.fingerprints.update(validate_service(svc))
        return svc

    # -- compile cache -------------------------------------------------------

    def _compiled(self, kind: str, statics: tuple, build_fn, args: tuple):
        """One AOT executable per (kind, statics) bucket.  The executable is
        lowered and compiled exactly once; subsequent calls with any batch
        that pads into the same bucket reuse it with zero retracing."""
        key = (kind, statics)
        exe = self._cache.get(key)
        if exe is None:
            faults.fire(f"compile:{kind}")
            exe = jax.jit(build_fn()).lower(*args).compile()
            self._cache[key] = exe
            self.compile_counts[kind] = self.compile_counts.get(kind, 0) + 1
        return exe

    def _pad_batch(self, patterns):
        """Dense [B_bucket, m_bucket] pattern batch + lengths + true size.

        Every pattern passes the unified input gate first (see
        ``normalize_patterns``): structurally bad input raises
        InvalidQueryError; empty / over-long / out-of-alphabet patterns
        become empty queries with empty results."""
        patterns = normalize_patterns(
            patterns, sigma=self.coll.sigma, max_len=MAX_PATTERN_LEN
        )
        pats, lens = pad_patterns(patterns)
        B, m = pats.shape
        Bb, mb = _bucket_batch(B), _bucket_len(m)
        out = np.zeros((Bb, mb), np.int32)
        out[:B, :m] = pats
        lns = np.zeros(Bb, np.int32)
        lns[:B] = lens
        return jnp.asarray(out), jnp.asarray(lns), B

    def _knobs(self, engine: str):
        thresh = jnp.float32(self.occ_df_threshold)
        forced = jnp.int32(ENGINE_CODES[engine])
        return thresh, forced

    def _brute_window_for(self, kind: str, bucket_key: tuple, patterns,
                          engine: str, max_buf: int) -> int:
        """Dispatch-aware Brute-L window (ROADMAP item): sized per compile
        bucket from the planner's occ stats instead of the static
        ``max_buf``.

        The plan pass is one (cached) compiled program; the window is the
        power-of-two cover of the largest occ among brute-assigned queries,
        clamped to [BRUTE_WINDOW_FLOOR, max_buf], and grows monotonically
        per bucket so recompiles are bounded by lg(max_buf).  Results are
        unchanged: the brute executor masks the window against each query's
        true occ, and queries past max_buf truncate exactly as the
        reference path does."""
        if self.brute_window is not None:
            return min(self.brute_window, max_buf)
        plan = self.plan(patterns, engine)
        occ = plan["occ"][plan["engine"] == ENGINE_BRUTE]
        needed = int(occ.max()) if occ.size else 0
        win = min(max(_pow2_ceil(needed), BRUTE_WINDOW_FLOOR), max_buf)
        key = (kind, bucket_key)
        win = max(win, self._brute_windows.get(key, 0))
        self._brute_windows[key] = win
        return win

    # -- planned endpoints (single compiled program per shape bucket) --------

    def plan(self, patterns, engine: str = "auto"):
        """Query plan for a pattern batch: host arrays (lo, hi, occ, df,
        engine), trimmed to the true batch size."""
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        faults.fire("plan")
        exe = self._compiled(
            "plan", (pats.shape,),
            lambda: functools.partial(_plan_program, self.use_search_kernel),
            (self.csa, self.sada, pats, lens, thresh, forced),
        )
        plan = exe(self.csa, self.sada, pats, lens, thresh, forced)
        return {
            name: np.asarray(getattr(plan, name))[:B]
            for name in ("lo", "hi", "occ", "df", "engine")
        }

    def ranges(self, patterns):
        p = self.plan(patterns)
        norm = normalize_patterns(
            patterns, sigma=self.coll.sigma, max_len=MAX_PATTERN_LEN
        )
        lens = np.asarray([len(x) for x in norm], np.int32)
        return p["lo"], p["hi"], lens

    def count(self, patterns, engine: str = "auto"):
        """df per pattern (Sada variant; ILCP counting cross-checks).

        ``engine="reference"`` computes the same counts through the
        per-query host path — the runtime's last-resort degradation."""
        if engine.startswith("reference"):
            return self._ranges_dfs(patterns)[2]
        return self.plan(patterns)["df"]

    def count_ilcp(self, patterns):
        lo, hi, lens = self.ranges(patterns)
        return np.asarray(
            ilcp_count_docs_batch(
                self.ilcp, jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(lens)
            )
        )

    def list_docs_arrays(self, patterns, max_df: int = 256, engine: str = "auto",
                         max_buf: int = 4096):
        """Array-level listing endpoint: (docs int32[B, max_df] ascending,
        -1 padded, counts int32[B]) — the zero-copy serving layout."""
        if not len(patterns):
            return np.zeros((0, max_df), np.int32), np.zeros(0, np.int32)
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        win = self._brute_window_for(
            "list", (pats.shape, max_df, max_buf), patterns, engine, max_buf
        )
        faults.fire("executor:list")
        args = (self.csa, self.ilcp, self.pdl_list, self.da, self.sada,
                pats, lens, thresh, forced)
        exe = self._compiled(
            "list", (pats.shape, max_df, win, max_buf),
            lambda: functools.partial(
                _list_program, max_df, win, max_buf,
                self.use_search_kernel, self.use_list_kernel,
            ),
            args,
        )
        docs, cnt, _plan = exe(*args)
        return faults.poison(
            "executor:list", (np.asarray(docs)[:B], np.asarray(cnt)[:B])
        )

    def list_docs(self, patterns, max_df: int = 256, engine: str = "auto",
                  max_buf: int = 4096):
        """Document listing with the paper's df/occ dispatch policy.

        ``engine``: "auto" | "brute" | "ilcp" | "pdl" run on the batched
        engine; "reference" (or "reference:<engine>") runs the per-query
        host loop — the parity oracle."""
        if engine.startswith("reference"):
            sub = engine.split(":", 1)[1] if ":" in engine else "auto"
            return self._list_docs_reference(patterns, max_df, sub, max_buf)
        docs, cnt = self.list_docs_arrays(patterns, max_df, engine, max_buf)
        return [docs[i, : cnt[i]].tolist() for i in range(len(cnt))]

    def topk_arrays(self, patterns, k: int = 10, engine: str = "auto",
                    max_buf: int = 4096):
        """Array-level top-k endpoint: (docs int32[B, k] padded -1,
        tf int32[B, k]), ranked by (tf desc, id asc)."""
        if not len(patterns):
            return np.zeros((0, k), np.int32), np.zeros((0, k), np.int32)
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        max_df = self._topk_max_df(max_buf)
        win = self._brute_window_for(
            "topk", (pats.shape, k, max_buf), patterns, engine, max_buf
        )
        faults.fire("executor:topk")
        args = (self.csa, self.pdl_topk, self.sada, pats, lens, thresh, forced)
        exe = self._compiled(
            "topk", (pats.shape, k, max_df, win, max_buf),
            lambda: functools.partial(
                _topk_program, k, max_df, win, max_buf, self.use_search_kernel
            ),
            args,
        )
        docs, tfs, _plan = exe(*args)
        return faults.poison(
            "executor:topk", (np.asarray(docs)[:B], np.asarray(tfs)[:B])
        )

    def topk(self, patterns, k: int = 10, engine: str = "auto",
             max_buf: int = 4096):
        if engine.startswith("reference"):
            sub = engine.split(":", 1)[1] if ":" in engine else "auto"
            return self._topk_reference(patterns, k, sub, max_buf)
        docs, tfs = self.topk_arrays(patterns, k, engine, max_buf)
        return [
            [(int(d), int(t)) for d, t in zip(docs[i], tfs[i]) if d >= 0]
            for i in range(docs.shape[0])
        ]

    def tfidf_arrays(self, queries, k: int = 10, conjunctive: bool = False,
                     max_terms: int = 4, max_buf: int = 2048):
        """Array-level ranked multi-term endpoint: (docs int32[Q, k] padded
        -1, scores f32[Q, k])."""
        Q = len(queries)
        if Q == 0:
            return np.zeros((0, k), np.int32), np.zeros((0, k), np.float32)
        queries = [
            normalize_patterns(
                list(terms)[:max_terms], sigma=self.coll.sigma,
                max_len=MAX_PATTERN_LEN,
            )
            for terms in queries
        ]
        m = max((len(t) for terms in queries for t in terms), default=1)
        Qb, mb = _bucket_batch(Q), _bucket_len(max(m, 1))
        pats = np.zeros((Qb, max_terms, mb), np.int32)
        lens = np.zeros((Qb, max_terms), np.int32)
        for qi, terms in enumerate(queries):
            for ti, t in enumerate(terms):
                pats[qi, ti, : len(t)] = t
                lens[qi, ti] = len(t)
        pats = jnp.asarray(pats)
        lens = jnp.asarray(lens)
        faults.fire("executor:tfidf")
        args = (self.csa, self.pdl_topk, self.sada, pats, lens)
        exe = self._compiled(
            "tfidf", (pats.shape, k, conjunctive, max_buf),
            lambda: functools.partial(
                _tfidf_program, k, conjunctive, max_buf, self.use_search_kernel
            ),
            args,
        )
        docs, scores = exe(*args)
        return faults.poison(
            "executor:tfidf", (np.asarray(docs)[:Q], np.asarray(scores)[:Q])
        )

    def tfidf(self, queries, k: int = 10, conjunctive: bool = False,
              max_terms: int = 4, max_buf: int = 2048, engine: str = "auto"):
        """queries: list of term-pattern lists.  Returns ranked (doc, score)."""
        if engine.startswith("reference"):
            return self._tfidf_reference(queries, k, conjunctive, max_terms, max_buf)
        docs, scores = self.tfidf_arrays(queries, k, conjunctive, max_terms, max_buf)
        return [
            [(int(d), float(s)) for d, s in zip(docs[i], scores[i]) if d >= 0]
            for i in range(docs.shape[0])
        ]

    # -- reference per-query path (parity oracle) ----------------------------

    def _dispatch(self, occ: int, df: int, engine: str) -> str:
        if engine != "auto":
            return engine
        return "brute" if occ < self.occ_df_threshold * max(df, 1) else "pdl"

    def _ranges_dfs(self, patterns):
        # same input gate as the batched path (_pad_batch) so the reference
        # oracle and the planned pipeline agree on hardened inputs
        patterns = normalize_patterns(
            patterns, sigma=self.coll.sigma, max_len=MAX_PATTERN_LEN
        )
        pats, lens = pad_patterns(patterns)
        from repro.core.csa import csa_search_batch

        lo, hi = csa_search_batch(self.csa, jnp.asarray(pats), jnp.asarray(lens))
        # same contract as the planner: zero-length patterns are empty, not
        # the full range (keeps reference/batched parity bit-exact)
        hi = jnp.where(jnp.asarray(lens) > 0, hi, lo)
        dfs = sada_count_batch(self.sada, lo, hi)
        return np.asarray(lo), np.asarray(hi), np.asarray(dfs)

    def _list_docs_reference(self, patterns, max_df, engine, max_buf):
        if not len(patterns):
            return []
        lo, hi, dfs = self._ranges_dfs(patterns)
        out = []
        for qi in range(len(lo)):
            l, h = int(lo[qi]), int(hi[qi])
            if l >= h:
                out.append([])
                continue
            eng = self._dispatch(h - l, int(dfs[qi]), engine)
            if eng == "brute":
                # window min(occ, max_buf) covers the same positions as the
                # batched executor's fixed max_buf window (validity-masked)
                docs, cnt, _ = brute_list_csa(
                    self.csa, l, h, min(h - l, max_buf), max_df
                )
            elif eng == "ilcp":
                docs, cnt = ilcp_list_docs_da(self.ilcp, self.da, l, h, max_df)
            else:
                docs, cnt = pdl_list_docs(
                    self.pdl_list, self.csa, l, h, max_df, max_buf=max_buf
                )
            out.append(sorted(np.asarray(docs)[: int(cnt)].tolist()))
        return out

    def _topk_max_df(self, max_buf: int) -> int:
        return min(self.coll.d + 1, max_buf)

    def _topk_reference(self, patterns, k, engine, max_buf):
        if not len(patterns):
            return []
        lo, hi, dfs = self._ranges_dfs(patterns)
        max_df = self._topk_max_df(max_buf)
        out = []
        for qi in range(len(lo)):
            l, h = int(lo[qi]), int(hi[qi])
            if l >= h:
                out.append([])
                continue
            eng = self._dispatch(h - l, int(dfs[qi]), engine)
            if eng == "brute":
                d, c, f = brute_list_csa(
                    self.csa, l, h, min(h - l, max_buf), max_df
                )
                docs, tfs = brute_topk(d, c, f, k)
            else:
                docs, tfs = pdl_topk(self.pdl_topk, self.csa, l, h, k,
                                     max_buf=max_buf)
            out.append(
                [(int(d), int(t)) for d, t in zip(np.asarray(docs), np.asarray(tfs))
                 if d >= 0]
            )
        return out

    def _tfidf_reference(self, queries, k, conjunctive, max_terms, max_buf):
        Q = len(queries)
        ranges = np.zeros((Q, max_terms, 2), np.int32)
        valid = np.zeros((Q, max_terms), bool)
        for qi, terms in enumerate(queries):
            if not terms:
                continue
            lo, hi, _ = self._ranges_dfs(terms[:max_terms])
            for ti in range(len(lo)):
                ranges[qi, ti] = (lo[ti], hi[ti])
                valid[qi, ti] = True
        docs, scores = tfidf_topk_batch(
            self.pdl_topk, self.csa, self.sada, ranges, valid, k, conjunctive,
            max_buf=max_buf,
        )
        out = []
        for qi in range(Q):
            out.append(
                [(int(d), float(s)) for d, s in zip(np.asarray(docs[qi]),
                                                    np.asarray(scores[qi])) if d >= 0]
            )
        return out

    # -- introspection --------------------------------------------------------

    #: endpoint kinds with a compiled program per shape bucket (the compile
    #: cache's key space; ``count`` rides the ``plan`` program)
    ENDPOINT_KINDS = ("plan", "list", "topk", "tfidf")

    def endpoint_program(self, kind: str, *, use_kernel: bool | None = None,
                         use_list_kernel: bool | None = None,
                         max_df: int = 64, k: int = 10, max_buf: int = 512,
                         conjunctive: bool = False):
        """The exact fused program + example arguments the compile cache
        would lower for ``kind`` — exposed so ``repro.analysis`` can audit
        the jaxpr of every endpoint (launch counts, callbacks, dtypes,
        VMEM) without executing anything.

        Returns ``(fn, args_builder)`` where ``args_builder(B, m)`` makes
        the padded example arguments for a (batch-bucket, length-bucket)
        signature.  ``use_kernel=None`` / ``use_list_kernel=None`` inherit
        the service's backends (the latter only matters to ``list``)."""
        if use_kernel is None:
            use_kernel = self.use_search_kernel
        if use_list_kernel is None:
            use_list_kernel = self.use_list_kernel
        if kind == "plan":
            fn = functools.partial(_plan_program, use_kernel)

            def args(B, m):
                return (self.csa, self.sada) + self._audit_batch(B, m)
        elif kind == "list":
            fn = functools.partial(
                _list_program, max_df, min(BRUTE_WINDOW_FLOOR, max_buf),
                max_buf, use_kernel, use_list_kernel,
            )

            def args(B, m):
                return (self.csa, self.ilcp, self.pdl_list, self.da,
                        self.sada) + self._audit_batch(B, m)
        elif kind == "topk":
            fn = functools.partial(
                _topk_program, k, self._topk_max_df(max_buf),
                min(BRUTE_WINDOW_FLOOR, max_buf), max_buf, use_kernel,
            )

            def args(B, m):
                return (self.csa, self.pdl_topk, self.sada) + \
                    self._audit_batch(B, m)
        elif kind == "tfidf":
            fn = functools.partial(
                _tfidf_program, k, conjunctive, max_buf, use_kernel
            )

            def args(B, m):
                pats = jnp.zeros((B, 2, _bucket_len(m)), jnp.int32)
                lens = jnp.ones((B, 2), jnp.int32)
                return (self.csa, self.pdl_topk, self.sada, pats, lens)
        else:
            raise ValueError(f"unknown endpoint kind {kind!r}")
        return fn, args

    def _audit_batch(self, B: int, m: int):
        pats = jnp.zeros((B, _bucket_len(m)), jnp.int32)
        lens = jnp.ones(B, jnp.int32)
        return pats, lens, jnp.float32(self.occ_df_threshold), jnp.int32(-1)

    def trace_endpoint(self, kind: str, B: int = 8, m: int = 8, **kw):
        """ClosedJaxpr of one endpoint program at a (B, m) bucket — the
        auditor's raw material."""
        fn, args = self.endpoint_program(kind, **kw)
        return jax.make_jaxpr(fn)(*args(_bucket_batch(B), m))

    def compiled_executables(self) -> dict:
        """The live AOT compile cache, keyed (kind, statics) — exposed for
        post-hoc audits of what this process actually lowered."""
        return dict(self._cache)

    def space_report(self) -> dict:
        """Bits-per-character accounting in the paper's units."""
        n = self.coll.n
        return {
            "n": n,
            "d": self.coll.d,
            "csa_rlcsa_bpc": self.csa.modeled_bits_rlcsa() / n,
            "ilcp_listing_bpc": self.ilcp.modeled_bits_listing() / n,
            "ilcp_counting_bpc": self.ilcp.modeled_bits_counting() / n,
            "pdl_list_bpc": self.pdl_list.modeled_bits() / n,
            "pdl_topk_bpc": self.pdl_topk.modeled_bits() / n,
            "sada_bpc": self.sada.modeled_bits() / n,
            "bwt_runs": self.csa.bwt_runs,
            "ilcp_runs": self.ilcp.nruns,
        }
