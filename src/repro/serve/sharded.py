"""Mesh-sharded retrieval: per-shard index stacks + cross-shard merge.

The single-device ``RetrievalService`` holds the whole index pytree in one
memory domain; once the CSA wavelet matrix outgrows
``BACKWARD_SEARCH_VMEM_BUDGET`` the planner silently drops off the fused
Pallas backward-search kernel onto the XLA pair descent.  This module
restores the kernel path by sharding the collection over a 1-D ``docs``
mesh axis (``repro.dist.sharding``):

* **Partitioning** — documents are split into contiguous shards
  (``doc_shard_bounds``); each shard indexes its own sub-collection
  (``repro.core.suffix.subcollection``, global sigma preserved) with a full
  per-shard stack: CSA wavelet matrix, ILCP runs, PDL blocks, Sadakane
  counting.  Because every document ends in its own terminator and patterns
  never contain it, a pattern's matches inside a shard's documents are
  exactly its matches inside the shard's text: per-shard occ / df /
  document sets sum (disjoint-union) to the global answer.

* **Execution** — ONE ``jax.jit`` program per endpoint x shape bucket, AOT
  compiled into the same shape-bucketed cache as the single-device engine.
  Inside the program the per-shard executors are unrolled at trace time
  (the per-shard pytrees are heterogeneous — different n, runs, PDL
  grammars — so they cannot be stacked and vmapped); the fused
  backward-search kernel therefore launches once **per shard** with a
  per-shard VMEM footprint (the per-shard launch-count contract in
  ``repro.analysis.contracts``).  Per-shard results are stacked [S, ...],
  constrained to ``PartitionSpec("docs", ...)`` so the partitioner places
  each shard's compute with its output slice, and merged by a
  ``shard_map``-ped reduction stage.

* **Merge algebra** (all on device, collectives allowlisted to
  ``psum`` / ``all_gather``):

  - counting:  global df / occ are ``psum`` s of per-shard counts (exact:
    integer sums over disjoint document sets);
  - listing:   shard-local doc ids are offset by the shard's document
    base, ``all_gather`` ed, and merge-sorted ascending — no dedup is
    needed because shards are document-disjoint;
  - top-k:     per-shard top-k rows are gathered and k-way merged by the
    canonical (tf desc, id asc) key; the union of shard-local top-k lists
    is a superset of the global top-k because a document's tf is local to
    its shard;
  - tf-idf:    a first ``psum`` stage produces collection-wide df per
    term; each shard then scores its own candidates with the **global**
    idf weights and document count (``tfidf_topk_batch(dfs_batch=...,
    n_docs=...)``), so a document's float score is bit-identical to the
    unsharded program's (the fixed-term-order scorer in
    ``repro.core.tfidf``); a final gather + (score desc, id asc) merge
    ranks the union.

Placement note: ``jax.jit`` rejects mixed single-device placements, so the
per-shard index leaves are placed **replicated** over the docs mesh
(``docs_index_shardings``) and the partitioner is steered by the output
constraints alone.  True per-device residency (shard s's leaves living
only on device s) is the multi-host follow-up recorded in
docs/SHARDING.md; the kernel-path restoration is unaffected because the
kernel's working set is the per-launch (per-shard) wavelet matrix.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import IDX
from repro.core.sada import sada_count_batch
from repro.core.suffix import Collection, subcollection
from repro.core.tfidf import rank_topk_scores, term_ranges_batch, tfidf_topk_batch
from repro.data.collections import normalize_patterns, pad_patterns
from repro.dist.sharding import (
    DOCS_AXIS,
    doc_shard_bounds,
    docs_index_shardings,
    docs_mesh_size,
    shard_map_compat,
)
from repro.serve import faults
from repro.serve.planner import ENGINE_BRUTE, ENGINE_CODES, plan_queries
from repro.serve.retrieval import (
    BRUTE_WINDOW_FLOOR,
    MAX_PATTERN_LEN,
    RetrievalService,
    _bucket_batch,
    _bucket_len,
    _list_program,
    _pow2_ceil,
    _topk_program,
)

_BIG = np.iinfo(np.int32).max


def _wsc(x, mesh):
    """Constrain a stacked [S, ...] per-shard result to the docs axis."""
    spec = P(DOCS_AXIS, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _shard_args(shards):
    """The per-shard index pytrees as one nested jit argument."""
    return tuple(
        (s.csa, s.ilcp, s.pdl_list, s.pdl_topk, s.sada, s.da) for s in shards
    )


# ---------------------------------------------------------------------------
# Fused sharded programs (ONE jit program per endpoint x bucket)
# ---------------------------------------------------------------------------


def _sharded_plan_program(
    mesh, doc_bases, use_kernel,
    shard_idx, patterns, lengths, threshold, forced,
):
    """Per-shard plans + psum-merged global occ / df.

    Returns (lo [S, B], hi [S, B], engine [S, B], occ [B], df [B]): ranges
    and engine choices are shard-local (each shard dispatches on its own
    occ/df balance), occurrence and document counts are collection-global.
    """
    lo, hi, occ, df, engine = [], [], [], [], []
    for csa, _ilcp, _pdl, _pdlt, sada, _da in shard_idx:
        plan = plan_queries(
            csa, sada, patterns, lengths, threshold, forced,
            use_kernel=use_kernel,
        )
        lo.append(plan.lo)
        hi.append(plan.hi)
        occ.append(plan.occ)
        df.append(plan.df)
        engine.append(plan.engine)
    occ_sb = _wsc(jnp.stack(occ), mesh)
    df_sb = _wsc(jnp.stack(df), mesh)

    def merge(occ_local, df_local):
        g_occ = jax.lax.psum(jnp.sum(occ_local, axis=0), DOCS_AXIS)
        g_df = jax.lax.psum(jnp.sum(df_local, axis=0), DOCS_AXIS)
        return g_occ, g_df

    g_occ, g_df = shard_map_compat(
        merge, mesh,
        in_specs=(P(DOCS_AXIS, None), P(DOCS_AXIS, None)),
        out_specs=(P(None), P(None)),
    )(occ_sb, df_sb)
    return jnp.stack(lo), jnp.stack(hi), jnp.stack(engine), g_occ, g_df


def _sharded_list_program(
    mesh, doc_bases, max_df, brute_win, max_buf, use_kernel, use_list_kernel,
    shard_idx, patterns, lengths, threshold, forced,
):
    """Listing: per-shard engines -> offset ids -> gather -> merge-sort.

    ``use_list_kernel`` rides through to each shard's ``_list_program``:
    on the kernel path the fused ILCP listing kernel launches once PER
    SHARD (like backward search), with a per-shard VMEM footprint —
    restoring the listing kernel for stacks past ILCP_LIST_VMEM_BUDGET."""
    per_docs, per_cnt = [], []
    for s, (csa, ilcp, pdl, _pdlt, sada, da) in enumerate(shard_idx):
        docs, cnt, _plan = _list_program(
            max_df, brute_win, max_buf, use_kernel, use_list_kernel,
            csa, ilcp, pdl, da, sada, patterns, lengths, threshold, forced,
        )
        per_docs.append(jnp.where(docs >= 0, docs + doc_bases[s], -1))
        per_cnt.append(cnt)
    docs_sb = _wsc(jnp.stack(per_docs), mesh)   # [S, B, max_df]
    cnt_sb = _wsc(jnp.stack(per_cnt), mesh)     # [S, B]

    def merge(docs_local, cnt_local):
        total = jax.lax.psum(jnp.sum(cnt_local, axis=0), DOCS_AXIS)
        allv = jax.lax.all_gather(docs_local, DOCS_AXIS, axis=0, tiled=True)
        S, B, W = allv.shape
        flat = jnp.swapaxes(allv, 0, 1).reshape(B, S * W)
        keys = jnp.where(flat < 0, _BIG, flat)
        s = jnp.sort(keys, axis=1)[:, :W]       # shards are doc-disjoint:
        docs = jnp.where(s == _BIG, -1, s)      # concat + sort, no dedup
        return docs.astype(IDX), jnp.minimum(total, W).astype(IDX)

    return shard_map_compat(
        merge, mesh,
        in_specs=(P(DOCS_AXIS, None, None), P(DOCS_AXIS, None)),
        out_specs=(P(None, None), P(None)),
    )(docs_sb, cnt_sb)


def _sharded_topk_program(
    mesh, doc_bases, k, max_df, brute_win, max_buf, use_kernel,
    shard_idx, patterns, lengths, threshold, forced,
):
    """Top-k: per-shard top-k -> gather -> k-way merge by (tf desc, id asc).

    Exact because documents are shard-disjoint: a document's tf is computed
    entirely inside its shard, so every global top-k document appears in
    its own shard's local top-k."""
    per_docs, per_tf = [], []
    for s, (csa, _ilcp, _pdl, pdl_t, sada, _da) in enumerate(shard_idx):
        docs, tfs, _plan = _topk_program(
            k, max_df, brute_win, max_buf, use_kernel,
            csa, pdl_t, sada, patterns, lengths, threshold, forced,
        )
        per_docs.append(jnp.where(docs >= 0, docs + doc_bases[s], -1))
        per_tf.append(tfs)
    docs_sb = _wsc(jnp.stack(per_docs), mesh)   # [S, B, k]
    tf_sb = _wsc(jnp.stack(per_tf), mesh)

    def merge(docs_local, tf_local):
        alld = jax.lax.all_gather(docs_local, DOCS_AXIS, axis=0, tiled=True)
        allt = jax.lax.all_gather(tf_local, DOCS_AXIS, axis=0, tiled=True)
        S, B, K = alld.shape
        d2 = jnp.swapaxes(alld, 0, 1).reshape(B, S * K)
        t2 = jnp.swapaxes(allt, 0, 1).reshape(B, S * K)
        ok = d2 >= 0
        dkey = jnp.where(ok, d2, _BIG)
        tkey = jnp.where(ok, -t2, _BIG)
        order = jnp.lexsort((dkey, tkey), axis=-1)[:, :K]
        docs = jnp.take_along_axis(dkey, order, axis=1)
        tfs = jnp.take_along_axis(t2, order, axis=1)
        good = docs < _BIG
        return (
            jnp.where(good, docs, -1).astype(IDX),
            jnp.where(good, tfs, 0).astype(IDX),
        )

    return shard_map_compat(
        merge, mesh,
        in_specs=(P(DOCS_AXIS, None, None), P(DOCS_AXIS, None, None)),
        out_specs=(P(None, None), P(None, None)),
    )(docs_sb, tf_sb)


def _sharded_tfidf_program(
    mesh, doc_bases, n_docs, k, conjunctive, max_buf, use_kernel,
    shard_idx, patterns, lengths,
):
    """tf-idf in two merge stages: psum global df, then score per shard
    with global weights and gather-merge by (score desc, id asc)."""
    Q, T, _m = patterns.shape
    per_ranges, per_dfs = [], []
    valid = None
    for csa, _ilcp, _pdl, _pdlt, sada, _da in shard_idx:
        ranges, valid = term_ranges_batch(
            csa, patterns, lengths, use_kernel=use_kernel
        )
        flat = ranges.reshape(Q * T, 2)
        dfs = sada_count_batch(sada, flat[:, 0], flat[:, 1]).reshape(Q, T)
        per_ranges.append(ranges)
        per_dfs.append(dfs)
    dfs_sb = _wsc(jnp.stack(per_dfs), mesh)     # [S, Q, T]

    def merge_df(dfs_local):
        return jax.lax.psum(jnp.sum(dfs_local, axis=0), DOCS_AXIS)

    g_dfs = shard_map_compat(
        merge_df, mesh,
        in_specs=P(DOCS_AXIS, None, None),
        out_specs=P(None, None),
    )(dfs_sb)                                   # [Q, T] global df, replicated

    per_docs, per_scores = [], []
    for s, (csa, _ilcp, _pdl, pdl_t, sada, _da) in enumerate(shard_idx):
        docs, scores = tfidf_topk_batch(
            pdl_t, csa, sada, per_ranges[s], valid, k, conjunctive,
            max_buf=max_buf, dfs_batch=g_dfs, n_docs=n_docs,
        )
        per_docs.append(jnp.where(docs >= 0, docs + doc_bases[s], -1))
        per_scores.append(scores)
    docs_sb = _wsc(jnp.stack(per_docs), mesh)     # [S, Q, k]
    score_sb = _wsc(jnp.stack(per_scores), mesh)

    def merge(docs_local, score_local):
        alld = jax.lax.all_gather(docs_local, DOCS_AXIS, axis=0, tiled=True)
        alls = jax.lax.all_gather(score_local, DOCS_AXIS, axis=0, tiled=True)
        S, Qb, K = alld.shape
        d2 = jnp.swapaxes(alld, 0, 1).reshape(Qb, S * K)
        s2 = jnp.swapaxes(alls, 0, 1).reshape(Qb, S * K)
        ok = d2 >= 0
        dkey = jnp.where(ok, d2, _BIG)
        md, ms = jax.vmap(lambda dd, ss, oo: rank_topk_scores(dd, ss, oo, K))(
            dkey, s2, ok
        )
        return md, ms

    return shard_map_compat(
        merge, mesh,
        in_specs=(P(DOCS_AXIS, None, None), P(DOCS_AXIS, None, None)),
        out_specs=(P(None, None), P(None, None)),
    )(docs_sb, score_sb)


# ---------------------------------------------------------------------------
# Service
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedRetrievalService:
    """Docs-mesh-sharded drop-in for ``RetrievalService``.

    Serves the same endpoint surface (``plan`` / ``count`` /
    ``list_docs[_arrays]`` / ``topk[_arrays]`` / ``tfidf[_arrays]``, with
    ``engine=`` including the ``"reference"`` oracle), so ``ServeRuntime``
    and the benchmarks run unchanged on top of it."""

    coll: Collection                  # the global collection
    mesh: object                      # 1-D ("docs",) mesh
    shards: list                      # per-shard RetrievalService stacks
    doc_bases: np.ndarray             # int32[S] first global doc id per shard
    occ_df_threshold: float = 4.0
    use_search_kernel: bool = False
    use_list_kernel: bool = False
    brute_window: int | None = None
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)
    _brute_windows: dict = dataclasses.field(default_factory=dict, repr=False)
    compile_counts: dict = dataclasses.field(default_factory=dict, repr=False)
    fingerprints: dict = dataclasses.field(default_factory=dict, repr=False)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls, coll: Collection, mesh, block_size: int = 64, beta: float = 16.0,
        sada_variant: str = "sparse", sample_rate: int = 16,
        use_search_kernel: bool | None = None,
        use_list_kernel: bool | None = None,
        brute_window: int | None = None,
        validate: bool = True,
    ):
        n_shards = docs_mesh_size(mesh)
        bounds = doc_shard_bounds(coll.d, n_shards)
        if use_search_kernel is None:
            use_search_kernel = jax.default_backend() == "tpu"
        if use_list_kernel is None:
            use_list_kernel = jax.default_backend() == "tpu"
        shards = []
        for dlo, dhi in bounds:
            sub = subcollection(coll, dlo, dhi)
            shard = RetrievalService.build(
                sub, block_size=block_size, beta=beta,
                sada_variant=sada_variant, sample_rate=sample_rate,
                use_search_kernel=use_search_kernel,
                use_list_kernel=use_list_kernel,
                brute_window=brute_window, validate=False,
            )
            # jit rejects mixed single-device placements: leaves live
            # replicated over the docs mesh (see module docstring)
            for name in ("csa", "ilcp", "pdl_list", "pdl_topk", "sada", "da"):
                leaf = getattr(shard, name)
                setattr(
                    shard, name,
                    jax.device_put(leaf, docs_index_shardings(mesh, leaf)),
                )
            shards.append(shard)
        svc = cls(
            coll=coll,
            mesh=mesh,
            shards=shards,
            doc_bases=np.asarray([b[0] for b in bounds], np.int32),
            use_search_kernel=use_search_kernel,
            use_list_kernel=use_list_kernel,
            brute_window=brute_window,
        )
        if validate:
            from repro.serve.validate import validate_sharded_service

            svc.fingerprints.update(validate_sharded_service(svc))
        return svc

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_doc_range(self, s: int) -> tuple[int, int]:
        lo = int(self.doc_bases[s])
        hi = (
            int(self.doc_bases[s + 1])
            if s + 1 < self.n_shards else self.coll.d
        )
        return lo, hi

    # -- compile cache (same discipline as RetrievalService) -----------------

    def _compiled(self, kind: str, statics: tuple, build_fn, args: tuple):
        key = (kind, statics)
        exe = self._cache.get(key)
        if exe is None:
            faults.fire(f"compile:{kind}")
            exe = jax.jit(build_fn()).lower(*args).compile()
            self._cache[key] = exe
            self.compile_counts[kind] = self.compile_counts.get(kind, 0) + 1
        return exe

    def _pad_batch(self, patterns):
        patterns = normalize_patterns(
            patterns, sigma=self.coll.sigma, max_len=MAX_PATTERN_LEN
        )
        pats, lens = pad_patterns(patterns)
        B, m = pats.shape
        Bb, mb = _bucket_batch(B), _bucket_len(m)
        out = np.zeros((Bb, mb), np.int32)
        out[:B, :m] = pats
        lns = np.zeros(Bb, np.int32)
        lns[:B] = lens
        return jnp.asarray(out), jnp.asarray(lns), B

    def _knobs(self, engine: str):
        return (
            jnp.float32(self.occ_df_threshold),
            jnp.int32(ENGINE_CODES[engine]),
        )

    def _brute_window_for(self, kind, bucket_key, patterns, engine, max_buf):
        """One Brute-L window shared by every shard, sized from the largest
        brute-assigned *per-shard* occ (grow-only, as in the single-device
        cache)."""
        if self.brute_window is not None:
            return min(self.brute_window, max_buf)
        plan = self.plan(patterns, engine)
        occ_sb = plan["hi"] - plan["lo"]                 # [S, B] shard-local
        brute = occ_sb[plan["engine_shard"] == ENGINE_BRUTE]
        needed = int(brute.max()) if brute.size else 0
        win = min(max(_pow2_ceil(needed), BRUTE_WINDOW_FLOOR), max_buf)
        key = (kind, bucket_key)
        win = max(win, self._brute_windows.get(key, 0))
        self._brute_windows[key] = win
        return win

    # -- endpoints -----------------------------------------------------------

    def plan(self, patterns, engine: str = "auto"):
        """Sharded query plan: global ``occ`` / ``df`` [B] (psum-merged),
        shard-local ``lo`` / ``hi`` / ``engine_shard`` [S, B].  ``engine``
        mirrors the single-device dict key for the global entries."""
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        faults.fire("plan")
        args = (_shard_args(self.shards), pats, lens, thresh, forced)
        exe = self._compiled(
            "plan", (pats.shape,),
            lambda: functools.partial(
                _sharded_plan_program, self.mesh, tuple(self.doc_bases),
                self.use_search_kernel,
            ),
            args,
        )
        lo, hi, eng, occ, df = exe(*args)
        return {
            "lo": np.asarray(lo)[:, :B],
            "hi": np.asarray(hi)[:, :B],
            "engine_shard": np.asarray(eng)[:, :B],
            "occ": np.asarray(occ)[:B],
            "df": np.asarray(df)[:B],
        }

    def count(self, patterns, engine: str = "auto"):
        if engine.startswith("reference"):
            return sum(
                np.asarray(sh._ranges_dfs(patterns)[2], np.int64).astype(np.int32)
                for sh in self.shards
            )
        return self.plan(patterns)["df"]

    def list_docs_arrays(self, patterns, max_df: int = 256,
                         engine: str = "auto", max_buf: int = 4096):
        if not len(patterns):
            return np.zeros((0, max_df), np.int32), np.zeros(0, np.int32)
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        win = self._brute_window_for(
            "list", (pats.shape, max_df, max_buf), patterns, engine, max_buf
        )
        faults.fire("executor:list")
        args = (_shard_args(self.shards), pats, lens, thresh, forced)
        exe = self._compiled(
            "list", (pats.shape, max_df, win, max_buf),
            lambda: functools.partial(
                _sharded_list_program, self.mesh, tuple(self.doc_bases),
                max_df, win, max_buf, self.use_search_kernel,
                self.use_list_kernel,
            ),
            args,
        )
        docs, cnt = exe(*args)
        return faults.poison(
            "executor:list", (np.asarray(docs)[:B], np.asarray(cnt)[:B])
        )

    def list_docs(self, patterns, max_df: int = 256, engine: str = "auto",
                  max_buf: int = 4096):
        if engine.startswith("reference"):
            sub = engine.split(":", 1)[1] if ":" in engine else "auto"
            return self._list_docs_reference(patterns, max_df, sub, max_buf)
        docs, cnt = self.list_docs_arrays(patterns, max_df, engine, max_buf)
        return [docs[i, : cnt[i]].tolist() for i in range(len(cnt))]

    def topk_arrays(self, patterns, k: int = 10, engine: str = "auto",
                    max_buf: int = 4096):
        if not len(patterns):
            return np.zeros((0, k), np.int32), np.zeros((0, k), np.int32)
        pats, lens, B = self._pad_batch(patterns)
        thresh, forced = self._knobs(engine)
        max_df = self._topk_max_df(max_buf)
        win = self._brute_window_for(
            "topk", (pats.shape, k, max_buf), patterns, engine, max_buf
        )
        faults.fire("executor:topk")
        args = (_shard_args(self.shards), pats, lens, thresh, forced)
        exe = self._compiled(
            "topk", (pats.shape, k, max_df, win, max_buf),
            lambda: functools.partial(
                _sharded_topk_program, self.mesh, tuple(self.doc_bases),
                k, max_df, win, max_buf, self.use_search_kernel,
            ),
            args,
        )
        docs, tfs = exe(*args)
        return faults.poison(
            "executor:topk", (np.asarray(docs)[:B], np.asarray(tfs)[:B])
        )

    def topk(self, patterns, k: int = 10, engine: str = "auto",
             max_buf: int = 4096):
        if engine.startswith("reference"):
            sub = engine.split(":", 1)[1] if ":" in engine else "auto"
            return self._topk_reference(patterns, k, sub, max_buf)
        docs, tfs = self.topk_arrays(patterns, k, engine, max_buf)
        return [
            [(int(d), int(t)) for d, t in zip(docs[i], tfs[i]) if d >= 0]
            for i in range(docs.shape[0])
        ]

    def _topk_max_df(self, max_buf: int) -> int:
        # per-shard rows: a shard holds at most its own documents + 1
        d_max = max(sh.coll.d for sh in self.shards)
        return min(d_max + 1, max_buf)

    def tfidf_arrays(self, queries, k: int = 10, conjunctive: bool = False,
                     max_terms: int = 4, max_buf: int = 2048):
        Q = len(queries)
        if Q == 0:
            return np.zeros((0, k), np.int32), np.zeros((0, k), np.float32)
        queries = [
            normalize_patterns(
                list(terms)[:max_terms], sigma=self.coll.sigma,
                max_len=MAX_PATTERN_LEN,
            )
            for terms in queries
        ]
        m = max((len(t) for terms in queries for t in terms), default=1)
        Qb, mb = _bucket_batch(Q), _bucket_len(max(m, 1))
        pats = np.zeros((Qb, max_terms, mb), np.int32)
        lens = np.zeros((Qb, max_terms), np.int32)
        for qi, terms in enumerate(queries):
            for ti, t in enumerate(terms):
                pats[qi, ti, : len(t)] = t
                lens[qi, ti] = len(t)
        pats = jnp.asarray(pats)
        lens = jnp.asarray(lens)
        faults.fire("executor:tfidf")
        args = (_shard_args(self.shards), pats, lens)
        exe = self._compiled(
            "tfidf", (pats.shape, k, conjunctive, max_buf),
            lambda: functools.partial(
                _sharded_tfidf_program, self.mesh, tuple(self.doc_bases),
                self.coll.d, k, conjunctive, max_buf, self.use_search_kernel,
            ),
            args,
        )
        docs, scores = exe(*args)
        return faults.poison(
            "executor:tfidf", (np.asarray(docs)[:Q], np.asarray(scores)[:Q])
        )

    def tfidf(self, queries, k: int = 10, conjunctive: bool = False,
              max_terms: int = 4, max_buf: int = 2048, engine: str = "auto"):
        if engine.startswith("reference"):
            return self._tfidf_reference(queries, k, conjunctive, max_terms,
                                         max_buf)
        docs, scores = self.tfidf_arrays(queries, k, conjunctive, max_terms,
                                         max_buf)
        return [
            [(int(d), float(s)) for d, s in zip(docs[i], scores[i]) if d >= 0]
            for i in range(docs.shape[0])
        ]

    # -- reference path: per-shard host oracles + host merge -----------------

    def _list_docs_reference(self, patterns, max_df, engine, max_buf):
        if not len(patterns):
            return []
        per = [
            sh._list_docs_reference(patterns, max_df, engine, max_buf)
            for sh in self.shards
        ]
        out = []
        for qi in range(len(per[0])):
            merged = sorted(
                int(d) + int(self.doc_bases[s])
                for s, rows in enumerate(per)
                for d in rows[qi]
            )
            out.append(merged[:max_df])
        return out

    def _topk_reference(self, patterns, k, engine, max_buf):
        if not len(patterns):
            return []
        per = [
            sh._topk_reference(patterns, k, engine, max_buf)
            for sh in self.shards
        ]
        out = []
        for qi in range(len(per[0])):
            pool = [
                (int(d) + int(self.doc_bases[s]), int(t))
                for s, rows in enumerate(per)
                for d, t in rows[qi]
            ]
            pool.sort(key=lambda dt: (-dt[1], dt[0]))
            out.append(pool[:k])
        return out

    def _tfidf_reference(self, queries, k, conjunctive, max_terms, max_buf):
        """Per-shard scoring with *global* df / document count (the exact
        floats the device merge produces), ranked on host."""
        Q = len(queries)
        ranges = np.zeros((len(self.shards), Q, max_terms, 2), np.int32)
        valid = np.zeros((Q, max_terms), bool)
        dfs = np.zeros((Q, max_terms), np.int64)
        for s, sh in enumerate(self.shards):
            for qi, terms in enumerate(queries):
                if not terms:
                    continue
                lo, hi, df = sh._ranges_dfs(terms[:max_terms])
                for ti in range(len(lo)):
                    ranges[s, qi, ti] = (lo[ti], hi[ti])
                    valid[qi, ti] = True
                    dfs[qi, ti] += int(df[ti])
        out = [[] for _ in range(Q)]
        pools = [[] for _ in range(Q)]
        for s, sh in enumerate(self.shards):
            docs, scores = tfidf_topk_batch(
                sh.pdl_topk, sh.csa, sh.sada, ranges[s], valid, k,
                conjunctive, max_buf=max_buf,
                dfs_batch=dfs.astype(np.int32), n_docs=self.coll.d,
            )
            docs = np.asarray(docs)
            scores = np.asarray(scores)
            for qi in range(Q):
                pools[qi] += [
                    (int(d) + int(self.doc_bases[s]), float(w))
                    for d, w in zip(docs[qi], scores[qi]) if d >= 0
                ]
        for qi in range(Q):
            pools[qi].sort(key=lambda dw: (-dw[1], dw[0]))
            out[qi] = pools[qi][:k]
        return out

    # -- introspection (repro.analysis contract surface) ---------------------

    ENDPOINT_KINDS = ("plan", "list", "topk", "tfidf")

    def endpoint_program(self, kind: str, *, use_kernel: bool | None = None,
                         use_list_kernel: bool | None = None,
                         max_df: int = 64, k: int = 10, max_buf: int = 512,
                         conjunctive: bool = False):
        """(fn, args_builder) of the sharded fused program for ``kind`` —
        the contract auditor's tracing surface (per-shard launch counts,
        collective allowlist)."""
        if use_kernel is None:
            use_kernel = self.use_search_kernel
        if use_list_kernel is None:
            use_list_kernel = self.use_list_kernel
        bases = tuple(self.doc_bases)
        if kind == "plan":
            fn = functools.partial(
                _sharded_plan_program, self.mesh, bases, use_kernel
            )

            def args(B, m):
                return (_shard_args(self.shards),) + self._audit_batch(B, m)
        elif kind == "list":
            fn = functools.partial(
                _sharded_list_program, self.mesh, bases, max_df,
                min(BRUTE_WINDOW_FLOOR, max_buf), max_buf, use_kernel,
                use_list_kernel,
            )

            def args(B, m):
                return (_shard_args(self.shards),) + self._audit_batch(B, m)
        elif kind == "topk":
            fn = functools.partial(
                _sharded_topk_program, self.mesh, bases, k,
                self._topk_max_df(max_buf), min(BRUTE_WINDOW_FLOOR, max_buf),
                max_buf, use_kernel,
            )

            def args(B, m):
                return (_shard_args(self.shards),) + self._audit_batch(B, m)
        elif kind == "tfidf":
            fn = functools.partial(
                _sharded_tfidf_program, self.mesh, bases, self.coll.d,
                k, conjunctive, max_buf, use_kernel,
            )

            def args(B, m):
                pats = jnp.zeros((B, 2, _bucket_len(m)), jnp.int32)
                lens = jnp.ones((B, 2), jnp.int32)
                return (_shard_args(self.shards), pats, lens)
        else:
            raise ValueError(f"unknown endpoint kind {kind!r}")
        return fn, args

    def _audit_batch(self, B: int, m: int):
        pats = jnp.zeros((B, _bucket_len(m)), jnp.int32)
        lens = jnp.ones(B, jnp.int32)
        return pats, lens, jnp.float32(self.occ_df_threshold), jnp.int32(-1)

    def trace_endpoint(self, kind: str, B: int = 8, m: int = 8, **kw):
        fn, args = self.endpoint_program(kind, **kw)
        return jax.make_jaxpr(fn)(*args(_bucket_batch(B), m))

    def compiled_executables(self) -> dict:
        return dict(self._cache)

    def space_report(self) -> dict:
        per = [sh.space_report() for sh in self.shards]
        return {
            "n": self.coll.n,
            "d": self.coll.d,
            "n_shards": self.n_shards,
            "shards": per,
        }
