"""Serving layer: batched document-retrieval service (the paper's indexes
as a first-class serving feature), the resilient request runtime wrapped
around it (deadlines, retries, circuit breaking, graceful degradation),
deterministic fault injection, and index integrity validation."""

from repro.serve.retrieval import RetrievalService
from repro.serve.runtime import (
    Answer,
    CircuitBreaker,
    RuntimeConfig,
    ServeRuntime,
)

__all__ = [
    "Answer",
    "CircuitBreaker",
    "RetrievalService",
    "RuntimeConfig",
    "ServeRuntime",
]
