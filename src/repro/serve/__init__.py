"""Serving layer: batched document-retrieval service (the paper's indexes
as a first-class serving feature) and LM decode serving."""

from repro.serve.retrieval import RetrievalService

__all__ = ["RetrievalService"]
