"""Model zoo: the 10 assigned architectures.

transformer — llama-family dense + MoE decoders (5 LM archs)
nequip      — E(3)-equivariant interatomic potential (Cartesian-tensor form)
recsys      — FM, SASRec, AutoInt, DLRM-MLPerf
"""
