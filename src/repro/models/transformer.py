"""Llama-family decoder transformers: dense (Llama 3.x / Mistral / SmolLM)
and MoE with top-1 routing + shared expert and 3:1 chunked-local:global
attention interleave (Llama 4 Scout / Maverick).

Structure: layers are grouped for ``lax.scan``.  A *group* holds ``period``
sub-layer positions with static attention types (llama4: [local, local,
local, global]; dense archs: period=1, [global]); parameters are stacked
[n_groups, ...] per position so one scan step runs one group.  This keeps
the lowered HLO a single while-loop over groups — essential for compiling
88-layer / 400B-parameter configs in the multi-pod dry-run.

Attention: GQA via KV-head grouping; RoPE on local (or all dense) layers,
NoPE on llama4 global layers (iRoPE); chunked local attention reshapes the
sequence into 8k chunks, masking causally within each chunk.  The XLA
einsum path is the default (it is what the dry-run lowers and the SPMD
partitioner shards); ``attention_impl='flash'`` swaps in the Pallas kernel
on TPU.

MoE: top-1 (Switch-style) routed expert + always-on shared expert, dense
dispatch via one-hot einsum over the expert axis so the expert dimension
shards over the ``model`` axis (EP): per-chip each expert's weights live on
E/model chips and the dispatch einsum lowers to an all-to-all-free
reduce-scatter pattern under GSPMD.

Steps exposed (built in repro.launch.steps with pjit shardings):
  forward_train   tokens -> mean xent loss       (train_4k)
  forward_prefill tokens -> last logits + cache  (prefill_32k)
  forward_decode  token + cache + pos -> logits  (decode_32k, long_500k)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, rms_norm, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 1            # top-1 per the assigned configs
    shared_expert: bool = True
    d_ff_expert: Optional[int] = None  # defaults to d_ff
    capacity_factor: float = 1.25      # Switch-style; overflow tokens drop


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    moe: Optional[MoEConfig] = None
    # attention layout: period & which positions are chunked-local
    period: int = 1
    local_positions: tuple = ()          # e.g. (0, 1, 2) for llama4
    local_chunk: int = 8192
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    param_dtype: jnp.dtype = jnp.bfloat16
    act_dtype: jnp.dtype = jnp.bfloat16
    attention_impl: str = "xla"          # "xla" | "flash"
    # expert parallelism via shard_map (set by the cell registry on
    # production meshes; None = single-device local dispatch)
    ep_mesh: Any = None
    ep_dp_axes: tuple = ()
    ep_fsdp: bool = False                # weights carry a data-axis shard

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0
        return self.n_layers // self.period

    def param_count(self) -> int:
        dh = self.head_dim
        attn = self.d_model * dh * (self.n_heads + 2 * self.n_kv_heads) + (
            self.n_heads * dh * self.d_model
        )
        if self.moe:
            dff = self.moe.d_ff_expert or self.d_ff
            ffn = 3 * self.d_model * dff * self.moe.n_experts
            if self.moe.shared_expert:
                ffn += 3 * self.d_model * self.d_ff
            ffn += self.d_model * self.moe.n_experts  # router
        else:
            ffn = 3 * self.d_model * self.d_ff
        per_layer = attn + ffn + 2 * self.d_model
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + self.d_model

    def active_param_count(self) -> int:
        """6*N_active*D convention for MoE rooflines."""
        if not self.moe:
            return self.param_count()
        dh = self.head_dim
        attn = self.d_model * dh * (self.n_heads + 2 * self.n_kv_heads) + (
            self.n_heads * dh * self.d_model
        )
        dff = self.moe.d_ff_expert or self.d_ff
        ffn = 3 * self.d_model * dff * self.moe.top_k
        if self.moe.shared_expert:
            ffn += 3 * self.d_model * self.d_ff
        ffn += self.d_model * self.moe.n_experts
        per_layer = attn + ffn + 2 * self.d_model
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + self.d_model


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _sublayer_params(cfg: LMConfig, key, g: int):
    """One sub-layer position's stacked parameters ([n_groups, ...])."""
    dh = cfg.head_dim
    d = cfg.d_model
    keys = jax.random.split(key, 12)
    dt = cfg.param_dtype
    G = cfg.n_groups
    s = 0.02

    def mk(k, *shape):
        return (jax.random.normal(k, (G, *shape)) * s).astype(dt)

    p = {
        "attn_norm": jnp.ones((G, d), dt),
        # head-structured projections: the head axis shards over `model`
        "wq": mk(keys[0], d, cfg.n_heads, dh),
        "wk": mk(keys[1], d, cfg.n_kv_heads, dh),
        "wv": mk(keys[2], d, cfg.n_kv_heads, dh),
        "wo": mk(keys[3], cfg.n_heads, dh, d),
        "ffn_norm": jnp.ones((G, d), dt),
    }
    if cfg.moe:
        dff = cfg.moe.d_ff_expert or cfg.d_ff
        E = cfg.moe.n_experts
        p["router"] = mk(keys[4], d, E)
        p["we_gate"] = mk(keys[5], E, d, dff)
        p["we_up"] = mk(keys[6], E, d, dff)
        p["we_down"] = mk(keys[7], E, dff, d)
        if cfg.moe.shared_expert:
            p["ws_gate"] = mk(keys[8], d, cfg.d_ff)
            p["ws_up"] = mk(keys[9], d, cfg.d_ff)
            p["ws_down"] = mk(keys[10], cfg.d_ff, d)
    else:
        p["w_gate"] = mk(keys[5], d, cfg.d_ff)
        p["w_up"] = mk(keys[6], d, cfg.d_ff)
        p["w_down"] = mk(keys[7], cfg.d_ff, d)
    return p


def init_params(cfg: LMConfig, key):
    keys = jax.random.split(key, cfg.period + 3)
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(
            cfg.param_dtype
        ),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "blocks": {
            f"pos{p}": _sublayer_params(cfg, keys[p + 1], p)
            for p in range(cfg.period)
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab)) * 0.02
        ).astype(cfg.param_dtype)
    return params


def abstract_params(cfg: LMConfig):
    """ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _gqa_attention(cfg: LMConfig, q, k, v, causal_offset: int | None = 0,
                   q_block: int = 512):
    """q [B,S,H,Dh], k/v [B,Skv,K,Dh] -> [B,S,H,Dh].

    Blockwise over query chunks: each chunk materializes only a
    [B, H, q_block, Skv] score tile, never the full S x S matrix — this is
    what bounds activation memory for train_4k / prefill_32k on the
    production mesh (XLA-level flash; the Pallas kernel is the TPU fast
    path via attention_impl='flash').  The chunk loop is unrolled so
    cost_analysis sees the true FLOP total (scan bodies undercount).
    """
    B, S, H, Dh = q.shape
    K = k.shape[2]
    rep = H // K
    qg = q.reshape(B, S, K, rep, Dh)
    if cfg.attention_impl == "flash" and causal_offset is not None:
        from repro.kernels import flash_attention

        kr = jnp.repeat(k, rep, axis=2)
        vr = jnp.repeat(v, rep, axis=2)
        out = flash_attention(
            q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
            vr.transpose(0, 2, 1, 3), causal=True,
        )
        return out.transpose(0, 2, 1, 3)

    Skv = k.shape[1]
    qb = min(q_block, S)
    assert S % qb == 0, (S, qb)
    nq = S // qb
    kpos = jnp.arange(Skv)[None, :]

    # context parallelism: when heads don't divide the model axis (e.g.
    # 40 heads on a 16-way axis), shard the KV sequence dimension instead —
    # score tiles become [*, q_block, Skv/model]; GSPMD inserts the softmax
    # max/sum reductions and the PV partial-sum all-reduce.
    if cfg.ep_mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as _P

        dp = tuple(cfg.ep_dp_axes)
        dspec = dp if len(dp) > 1 else dp[0]
        mdl_ok = Skv % cfg.ep_mesh.shape["model"] == 0
        kv_spec = _P(dspec, "model" if mdl_ok else None, None, None)
        cst = lambda a, sp: jax.lax.with_sharding_constraint(
            a, NamedSharding(cfg.ep_mesh, sp)
        )
        if B % int(np.prod([cfg.ep_mesh.shape[a] for a in dp])) == 0:
            k = cst(k, kv_spec)
            v = cst(v, kv_spec)
            qg = cst(qg, _P(dspec, None, None, None, None))

    # scan over query chunks: exactly one [*, q_block, Skv] score tile is
    # live at a time (fwd and — with the checkpoint — bwd).  No collectives
    # exist inside the chunk body, so roofline trip-accounting is unaffected.
    @jax.checkpoint
    def chunk_attn(carry, xs):
        qc, qpos0 = xs
        logits = jnp.einsum("bqkrd,btkd->bkrqt", qc, k).astype(jnp.float32)
        logits = logits * (Dh ** -0.5)
        if causal_offset is not None:
            qpos = qpos0 + jnp.arange(qb)[:, None] + causal_offset
            mask = kpos <= qpos
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return carry, jnp.einsum("bkrqt,btkd->bqkrd", probs, v)

    q_chunks = qg.reshape(B, nq, qb, K, rep, Dh).transpose(1, 0, 2, 3, 4, 5)
    starts = jnp.arange(nq, dtype=jnp.int32) * qb
    _, outs = jax.lax.scan(chunk_attn, 0, (q_chunks, starts))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, rep, Dh)
    return out.reshape(B, S, H, Dh)


def _chunked_local_attention(cfg: LMConfig, q, k, v):
    """Causal attention within fixed chunks (llama4 local layers)."""
    B, S, H, Dh = q.shape
    C = min(cfg.local_chunk, S)
    assert S % C == 0
    nc = S // C
    K = k.shape[2]

    def resh(x, heads):
        return x.reshape(B * nc, C, heads, Dh)

    qc = q.reshape(B, nc, C, H, Dh).reshape(B * nc, C, H, Dh)
    kc = k.reshape(B, nc, C, K, Dh).reshape(B * nc, C, K, Dh)
    vc = v.reshape(B, nc, C, K, Dh).reshape(B * nc, C, K, Dh)
    out = _gqa_attention(cfg, qc, kc, vc, causal_offset=0)
    return out.reshape(B, nc, C, H, Dh).reshape(B, S, H, Dh)


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def _moe_ffn(cfg: LMConfig, p, x, capacity_factor: float | None = None):
    """Top-1 routed + shared expert, capacity-based sorted dispatch.

    Tokens are argsorted by expert id; each expert takes its first
    ``capacity`` tokens (Switch-style dropping).  Buffers are
    [E, capacity, D] with E sharded over ``model`` (EP), so memory is
    O(T * D + E * cap * D / ep) — never the dense [E, T, D] blowup.  The
    scatter/gather dispatch lowers to an all-to-all-like exchange under
    GSPMD.  Gradients flow through the gate weight (standard top-1).
    """
    B, S, D = x.shape
    E = cfg.moe.n_experts
    T = B * S
    if capacity_factor is None:
        capacity_factor = cfg.moe.capacity_factor
    cap = max(1, min(T, int(T / E * capacity_factor)))

    xf = x.reshape(T, D)
    scores = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    gate = jax.nn.softmax(scores, axis=-1)
    top = jnp.argmax(gate, axis=-1).astype(jnp.int32)              # [T]
    top_w = jnp.take_along_axis(gate, top[:, None], axis=-1)[:, 0]  # [T]

    if S == 1:
        # decode: no token may be dropped — compute all experts for the few
        # live tokens and select (E x T x F is small at T = batch)
        onehot = jax.nn.one_hot(top, E, dtype=x.dtype)              # [T, E]
        g = jax.nn.silu(jnp.einsum("td,edf->etf", xf, p["we_gate"]))
        u = jnp.einsum("td,edf->etf", xf, p["we_up"])
        ye = jnp.einsum("etf,efd->etd", g * u, p["we_down"])        # [E,T,D]
        y = jnp.einsum("etd,te->td", ye, onehot)
        y = (y * top_w[:, None].astype(x.dtype)).reshape(B, S, D)
        if cfg.moe.shared_expert:
            y = y + swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
        return y, jnp.float32(0)

    # stable sort by expert; slot within expert = sorted pos - expert start
    perm = jnp.argsort(top)                                         # [T]
    top_sorted = top[perm]
    expert_start = jnp.searchsorted(top_sorted, jnp.arange(E, dtype=jnp.int32))
    slot_sorted = jnp.arange(T, dtype=jnp.int32) - expert_start[top_sorted]
    keep = slot_sorted < cap

    # dispatch into [E, cap, D] (overflow tokens dropped)
    xe = jnp.zeros((E, cap, D), x.dtype)
    se = jnp.where(keep, top_sorted, E)            # OOB -> dropped
    ss = jnp.where(keep, slot_sorted, cap)
    xe = xe.at[se, ss].set(xf[perm], mode="drop")

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["we_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["we_down"])           # [E,cap,D]

    # combine: token at sorted pos s reads ye[expert, slot] (0 if dropped)
    gathered = ye[se, jnp.minimum(ss, cap - 1)]                     # [T, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((T, D), x.dtype).at[perm].set(gathered)
    y = (y * top_w[:, None].astype(x.dtype)).reshape(B, S, D)

    if cfg.moe.shared_expert:
        y = y + swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    # load-balance auxiliary loss (Switch): E * sum_e f_e * P_e
    fe = jnp.zeros(E, jnp.float32).at[top].add(1.0) / T
    pe = jnp.mean(gate, axis=0)
    aux = E * jnp.sum(fe * pe)
    return y, aux


def _dense_ffn(cfg: LMConfig, p, x):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0)


def _moe_ffn_ep(cfg: LMConfig, p, x, capacity_factor: float | None = None):
    """Expert parallelism with explicit collectives (shard_map).

    Routing and capacity dispatch are *local* to each data shard (a global
    token argsort under pjit forces activation replication — the reason
    this path exists); the [E, cap_local, D] buffers are exchanged across
    the `model` axis with all-to-all so each chip runs its E/ep experts,
    and FSDP-sharded expert weights all-gather their data-axis shard just
    before use.  This is the Switch/GShard execution scheme mapped onto
    jax.shard_map (DESIGN.md Section 5).
    """
    mesh = cfg.ep_mesh
    mdl = "model"
    dp = tuple(cfg.ep_dp_axes)
    E = cfg.moe.n_experts
    ep = mesh.shape[mdl]
    assert E % ep == 0, (E, ep)
    cf = capacity_factor or cfg.moe.capacity_factor
    B, S, D = x.shape
    import numpy as _np

    dpn = int(_np.prod([mesh.shape[a] for a in dp]))
    T_loc = (B // dpn) * S
    cap = max(1, min(T_loc, int(T_loc / E * cf)))
    P = jax.sharding.PartitionSpec
    dspec = dp if len(dp) > 1 else dp[0]

    def body(xl, router, wg, wu, wd):
        if cfg.ep_fsdp and dpn > 1:
            wg = jax.lax.all_gather(wg, dp, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, dp, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, dp, axis=1, tiled=True)
        Bl = xl.shape[0]
        xf = xl.reshape(Bl * S, D)
        T = xf.shape[0]
        scores = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
        gate = jax.nn.softmax(scores, axis=-1)
        top = jnp.argmax(gate, axis=-1).astype(jnp.int32)
        top_w = jnp.take_along_axis(gate, top[:, None], axis=-1)[:, 0]

        perm = jnp.argsort(top)
        top_sorted = top[perm]
        expert_start = jnp.searchsorted(top_sorted, jnp.arange(E, dtype=jnp.int32))
        slot_sorted = jnp.arange(T, dtype=jnp.int32) - expert_start[top_sorted]
        keep = slot_sorted < cap
        se = jnp.where(keep, top_sorted, E)
        ss = jnp.where(keep, slot_sorted, cap)
        xe = jnp.zeros((E, cap, D), xl.dtype).at[se, ss].set(xf[perm], mode="drop")

        # exchange: [E, cap, D] -> [E/ep, ep*cap, D]
        xe = jax.lax.all_to_all(xe, mdl, split_axis=0, concat_axis=1, tiled=True)

        # expert FFN, chunked over the token-capacity dim so the [*, F]
        # intermediates stay bounded (~2k tokens per tile); checkpointed so
        # the backward recomputes g/u per chunk instead of saving them
        cp = xe.shape[1]
        nch = max(1, cp // 2048)
        while cp % nch:
            nch -= 1
        cc = cp // nch

        @jax.checkpoint
        def ffn_chunk(carry, xc):
            g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xc, wg))
            u = jnp.einsum("ecd,edf->ecf", xc, wu)
            return carry, jnp.einsum("ecf,efd->ecd", g * u, wd)

        xch = xe.reshape(xe.shape[0], nch, cc, D).transpose(1, 0, 2, 3)
        _, ych = jax.lax.scan(ffn_chunk, 0, xch)
        ye = ych.transpose(1, 0, 2, 3).reshape(xe.shape[0], cp, D)

        ye = jax.lax.all_to_all(ye, mdl, split_axis=1, concat_axis=0, tiled=True)

        gathered = ye[se, jnp.minimum(ss, cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = jnp.zeros((T, D), xl.dtype).at[perm].set(gathered)
        y = (y * top_w[:, None].astype(xl.dtype)).reshape(Bl, S, D)

        fe = jnp.zeros(E, jnp.float32).at[top].add(1.0) / T
        pe = jnp.mean(gate, axis=0)
        aux = E * jnp.sum(fe * pe)
        aux = jax.lax.pmean(aux, dp + (mdl,))
        return y, aux

    from repro.dist.sharding import shard_map_compat

    f_dp = dspec if cfg.ep_fsdp else None
    y, aux = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(
            P(dspec, None, None),
            P(None, None),
            P(mdl, None, f_dp),
            P(mdl, None, f_dp),
            P(mdl, f_dp, None),
        ),
        out_specs=(P(dspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])

    if cfg.moe.shared_expert:
        y = y + swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])
    return y, aux


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _sublayer_train(cfg: LMConfig, pos: int, p, x, positions):
    """One decoder layer (training / prefill, full sequence)."""
    B, S, D = x.shape
    dh = cfg.head_dim
    local = pos in cfg.local_positions

    h = rms_norm(x, p["attn_norm"])
    q = jnp.einsum("bsd,dhe->bshe", h, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", h, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", h, p["wv"])
    if local or cfg.period == 1:
        # RoPE on local layers (and all layers of dense archs); llama4
        # global layers are NoPE (iRoPE)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if local:
        attn = _chunked_local_attention(cfg, q, k, v)
    else:
        attn = _gqa_attention(cfg, q, k, v, causal_offset=0)
    x = x + jnp.einsum("bshe,hed->bsd", attn, p["wo"])

    h = rms_norm(x, p["ffn_norm"])
    if cfg.moe:
        ffn = _moe_ffn_ep if cfg.ep_mesh is not None else _moe_ffn
    else:
        ffn = _dense_ffn
    y, aux = ffn(cfg, p, h)
    return x + y, aux, (k, v)


def forward_train(cfg: LMConfig, params, tokens, labels):
    """Mean next-token loss over [B, S] tokens."""
    x = params["embed"][tokens].astype(cfg.act_dtype)

    (x, aux), _ = jax.lax.scan(
        functools.partial(_remat_group, cfg),
        (x, jnp.float32(0)),
        params["blocks"],
    )
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    loss = _chunked_xent(cfg, x[:, :-1], head, labels[:, 1:])
    return loss + 0.01 * aux / cfg.n_groups


def _chunked_xent(cfg: LMConfig, x, head, labels, chunk: int = 512):
    """Cross entropy without materializing [B, S, V] logits: unrolled loop
    over sequence chunks; each step holds one [B, chunk, V] tile (vocab
    additionally sharded over `model` under pjit)."""
    B, S, D = x.shape
    head = head.astype(cfg.act_dtype)
    cb = min(chunk, S)
    nc = -(-S // cb)
    total = jnp.float32(0)
    count = jnp.float32(0)
    for c in range(nc):
        lo = c * cb
        width = min(cb, S - lo)
        xc = jax.lax.dynamic_slice_in_dim(x, lo, width, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, lo, width, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(logz - gold)
        count = count + jnp.float32(B * width)
    return total / count


def _remat_group(cfg: LMConfig, carry, block):
    """Scan body with activation checkpointing: only the group inputs are
    saved; everything inside the group recomputes in the backward pass.

    The saved carry (the residual stream) is *sequence-sharded* over the
    model axis (sequence parallelism, Korthikanti et al. 2022): without
    this, an 88-group 12k-wide model saves 88 x [B_loc, S, D] full-width
    residuals per device (~141 GB for mistral-large on the single-pod
    mesh).  Sharded, the per-group checkpoint is D*S/model — the boundary
    resharding lowers to reduce-scatter/all-gather pairs that replace the
    row-parallel all-reduces at the same wire bytes.
    """

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(x, aux):
        positions = jnp.arange(x.shape[1])[None, :]
        for pos in range(cfg.period):
            x, a, _ = _sublayer_train(cfg, pos, block[f"pos{pos}"], x, positions)
            aux = aux + a
        return x, aux

    x, aux = carry
    x, aux = body(x, aux)
    x = _seq_shard_constraint(cfg, x)
    return (x, aux), None


def _seq_shard_constraint(cfg: LMConfig, x):
    """Pin [B, S, D] activations to (data, model-on-S) sharding when a
    production mesh is attached and S divides the model axis."""
    if cfg.ep_mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as _P

    dp = tuple(cfg.ep_dp_axes)
    dspec = dp if len(dp) > 1 else dp[0]
    import numpy as _np

    dpn = int(_np.prod([cfg.ep_mesh.shape[a] for a in dp]))
    if x.shape[0] % dpn or x.shape[1] % cfg.ep_mesh.shape["model"]:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(cfg.ep_mesh, _P(dspec, "model", None))
    )


# ---------------------------------------------------------------------------
# Prefill / decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or cfg.act_dtype
    G = cfg.n_groups
    dh = cfg.head_dim
    return {
        f"pos{p}": {
            "k": jnp.zeros((G, batch, max_seq, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((G, batch, max_seq, cfg.n_kv_heads, dh), dt),
        }
        for p in range(cfg.period)
    }


def abstract_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


def forward_prefill(cfg: LMConfig, params, tokens):
    """Full-sequence forward returning (last-token logits, cache)."""
    x = params["embed"][tokens].astype(cfg.act_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]

    def group(x, block):
        kvs = {}
        for pos in range(cfg.period):
            x, _, (k, v) = _sublayer_train(cfg, pos, block[f"pos{pos}"], x, positions)
            kvs[f"pos{pos}"] = {"k": k, "v": v}
        return x, kvs

    x, cache = jax.lax.scan(group, x, params["blocks"])
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cfg.act_dtype))
    return logits, cache


def _sublayer_decode(cfg: LMConfig, pos, p, x, cache_kv, t):
    """One layer, one new token.  x [B, D]; cache k/v [B, Smax, K, Dh];
    t: current position (scalar int32)."""
    B, D = x.shape
    dh = cfg.head_dim
    local = pos in cfg.local_positions

    h = rms_norm(x, p["attn_norm"])
    q = jnp.einsum("bd,dhe->bhe", h, p["wq"])[:, None]
    k = jnp.einsum("bd,dhe->bhe", h, p["wk"])[:, None]
    v = jnp.einsum("bd,dhe->bhe", h, p["wv"])[:, None]
    posn = jnp.full((1, 1), t, jnp.int32)
    if local or cfg.period == 1:
        q = apply_rope(q, posn, cfg.rope_theta)
        k = apply_rope(k, posn, cfg.rope_theta)

    ck = jax.lax.dynamic_update_slice(cache_kv["k"], k.astype(cache_kv["k"].dtype), (0, t, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_kv["v"], v.astype(cache_kv["v"].dtype), (0, t, 0, 0))

    Smax = ck.shape[1]
    K = cfg.n_kv_heads
    rep = cfg.n_heads // K
    qg = q.reshape(B, K, rep, dh)
    logits = jnp.einsum("bkrd,btkd->bkrt", qg, ck).astype(jnp.float32)
    logits = logits * (dh ** -0.5)
    kpos = jnp.arange(Smax)[None, None, None, :]
    valid = kpos <= t
    if local:
        # chunked-local: only the current chunk attends
        chunk_start = (t // cfg.local_chunk) * cfg.local_chunk
        valid = valid & (kpos >= chunk_start)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    attn = jnp.einsum("bkrt,btkd->bkrd", probs, cv)
    attn = attn.reshape(B, cfg.n_heads, dh)
    x = x + jnp.einsum("bhe,hed->bd", attn, p["wo"])

    h = rms_norm(x, p["ffn_norm"])
    if cfg.moe:
        y, _ = _moe_ffn(cfg, p, h[:, None, :])
        y = y[:, 0]
    else:
        y = swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x + y, {"k": ck, "v": cv}


def forward_decode(cfg: LMConfig, params, token, cache, t):
    """One decode step: token [B] int32, cache pytree, t scalar position.
    Returns (logits [B, V], new cache)."""
    x = params["embed"][token].astype(cfg.act_dtype)

    def group(x, scans):
        block, cache_g = scans
        new_cache = {}
        for pos in range(cfg.period):
            x, kv = _sublayer_decode(
                cfg, pos, block[f"pos{pos}"], x, cache_g[f"pos{pos}"], t
            )
            new_cache[f"pos{pos}"] = kv
        return x, new_cache

    x, new_cache = jax.lax.scan(group, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bd,dv->bv", x, head.astype(cfg.act_dtype))
    return logits, new_cache
