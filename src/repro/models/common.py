"""Shared model building blocks: norms, RoPE, initializers, MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, weight, eps=1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def rope_freqs(d_head: int, theta: float = 500000.0):
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 500000.0):
    """x: [..., S, H, Dh]; positions: int32 broadcastable to [..., S]."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                    # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def mlp(x, weights, biases, act=jax.nn.relu, final_act=None):
    """Plain MLP over a list of (w, b)."""
    h = x
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = jnp.einsum("...d,df->...f", h, w) + b
        if i < len(weights) - 1:
            h = act(h)
        elif final_act is not None:
            h = final_act(h)
    return h


def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy.  logits [..., V], labels int [...]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
