"""NequIP (arXiv:2101.03164): E(3)-equivariant message-passing interatomic
potential, in a Cartesian-tensor formulation for l_max = 2.

TPU adaptation (recorded in DESIGN.md): instead of spherical-harmonic irrep
blocks with Clebsch-Gordan tables (awkward small gathers on the MXU/VPU),
features are kept as Cartesian tensors per node and channel:

    s [N, C]         l = 0 scalars
    v [N, C, 3]      l = 1 vectors
    t [N, C, 3, 3]   l = 2 symmetric traceless tensors

All tensor-product paths (l1 x l2 -> l3, l <= 2) become dense contractions
(dot, cross, matvec, symmetric-traceless outer), which are exactly-
equivariant under O(3)/SO(3) by construction and map onto batched einsums.
Path weights are per-(path, channel) functions of the edge length through a
Bessel radial basis + MLP, matching NequIP's radial nets.  Message passing
is edge-gather -> tensor product -> ``segment_sum`` scatter, the JAX-native
sparse pattern the assignment mandates.

Config (assigned): n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import mlp

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat_in: int = 1433       # input node feature width (dataset-dependent)
    radial_hidden: int = 64
    readout_hidden: int = 64
    param_dtype: jnp.dtype = jnp.float32

    @property
    def n_paths(self) -> int:
        return 10


def init_params(cfg: NequIPConfig, key):
    keys = jax.random.split(key, 4 + cfg.n_layers)
    C = cfg.channels
    dt = cfg.param_dtype

    def dense(k, din, dout, scale=None):
        scale = scale or (din ** -0.5)
        return (jax.random.normal(k, (din, dout)) * scale).astype(dt)

    params = {
        "embed_in": dense(keys[0], cfg.d_feat_in, C),
        "layers": [],
        "readout_w1": dense(keys[1], C, cfg.readout_hidden),
        "readout_b1": jnp.zeros((cfg.readout_hidden,), dt),
        "readout_w2": dense(keys[2], cfg.readout_hidden, 1),
        "readout_b2": jnp.zeros((1,), dt),
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 8)
        layer = {
            # radial net: rbf -> hidden -> per-(path, channel) weights
            "rad_w1": dense(lk[0], cfg.n_rbf, cfg.radial_hidden),
            "rad_b1": jnp.zeros((cfg.radial_hidden,), dt),
            "rad_w2": dense(lk[1], cfg.radial_hidden, cfg.n_paths * C),
            "rad_b2": jnp.zeros((cfg.n_paths * C,), dt),
            # self-interaction channel mixes (per l)
            "mix_s_self": dense(lk[2], C, C),
            "mix_s_msg": dense(lk[3], C, C),
            "mix_v_self": dense(lk[4], C, C),
            "mix_v_msg": dense(lk[5], C, C),
            "mix_t_self": dense(lk[6], C, C),
            "mix_t_msg": dense(lk[7], C, C),
            # gates for l > 0 (functions of scalars)
            "gate_v": dense(lk[2], C, C, 0.1),
            "gate_t": dense(lk[3], C, C, 0.1),
        }
        params["layers"].append(layer)
    return params


def abstract_params(cfg: NequIPConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Geometry pieces
# ---------------------------------------------------------------------------


def bessel_rbf(r, n_rbf: int, cutoff: float):
    """Bessel radial basis sin(n pi r / rc) / r with smooth polynomial
    envelope (NequIP's choice)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rc = cutoff
    rr = jnp.maximum(r, EPS)[..., None]
    basis = jnp.sqrt(2.0 / rc) * jnp.sin(n * jnp.pi * rr / rc) / rr
    # polynomial cutoff envelope (p = 6)
    x = jnp.clip(r / rc, 0.0, 1.0)
    env = 1 - 28 * x**6 + 48 * x**7 - 21 * x**8
    return basis * env[..., None]


def edge_harmonics(edge_vec):
    """Y0 = 1, Y1 = unit vector, Y2 = traceless symmetric outer product."""
    r = jnp.linalg.norm(edge_vec, axis=-1)
    u = edge_vec / jnp.maximum(r, EPS)[..., None]
    eye = jnp.eye(3, dtype=edge_vec.dtype)
    y2 = u[..., :, None] * u[..., None, :] - eye / 3.0
    return r, u, y2


def _sym_traceless(m):
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    return sym - tr * jnp.eye(3, dtype=m.dtype) / 3.0


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _edge_messages(cfg: NequIPConfig, lp, s, v, t, src, dst, r, u, y2, n_nodes):
    """Tensor-product messages for one edge block + scatter to receivers."""
    C = cfg.channels
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    w = mlp(
        rbf,
        [lp["rad_w1"], lp["rad_w2"]],
        [lp["rad_b1"], lp["rad_b2"]],
        act=jax.nn.silu,
    ).reshape(-1, cfg.n_paths, C)                          # [E, P, C]

    ss = s[src]                                            # [E, C]
    vs = v[src]                                            # [E, C, 3]
    ts = t[src]                                            # [E, C, 3, 3]
    u_ = u[:, None, :]                                     # [E, 1, 3]
    y2_ = y2[:, None, :, :]                                # [E, 1, 3, 3]

    # --- tensor-product paths (l1 x l2 -> l3), all l <= 2 -----------------
    # to scalars
    m_s = (
        w[:, 0] * ss
        + w[:, 1] * jnp.einsum("eci,ei->ec", vs, u)
        + w[:, 2] * jnp.einsum("ecij,eij->ec", ts, y2)
    )
    # to vectors
    m_v = (
        w[:, 3][..., None] * (ss[..., None] * u_)
        + w[:, 4][..., None] * vs
        + w[:, 5][..., None] * jnp.cross(vs, jnp.broadcast_to(u_, vs.shape))
        + w[:, 6][..., None] * jnp.einsum("ecij,ej->eci", ts, u)
    )
    # to l = 2 tensors
    outer_vu = _sym_traceless(vs[..., :, None] * u_[..., None, :])
    m_t = (
        w[:, 7][..., None, None] * (ss[..., None, None] * y2_)
        + w[:, 8][..., None, None] * ts
        + w[:, 9][..., None, None] * outer_vu
    )
    agg_s = jax.ops.segment_sum(m_s, dst, num_segments=n_nodes)
    agg_v = jax.ops.segment_sum(m_v, dst, num_segments=n_nodes)
    agg_t = jax.ops.segment_sum(m_t, dst, num_segments=n_nodes)
    return agg_s, agg_v, agg_t


def _message_layer(
    cfg: NequIPConfig, lp, s, v, t, edge_index, r, u, y2, n_nodes,
    n_edge_chunks: int = 1,
):
    """One interaction block.

    Edge blocking (GE-SpMM-style tiling): per-edge tensor messages at
    61.8M edges x 32 channels x 9 components would be terabytes; a scan
    over edge chunks keeps only one chunk's messages live while node-level
    aggregates accumulate in the carry.  Chunk count is a shape-level knob
    (configs set it so a chunk's messages fit per-device VMEM/HBM budget).
    """
    src, dst = edge_index[0], edge_index[1]
    E = src.shape[0]
    if n_edge_chunks <= 1:
        agg_s, agg_v, agg_t = _edge_messages(
            cfg, lp, s, v, t, src, dst, r, u, y2, n_nodes
        )
    else:
        assert E % n_edge_chunks == 0, (E, n_edge_chunks)
        ce = E // n_edge_chunks

        def chunk(carry, xs):
            a_s, a_v, a_t = carry
            src_c, dst_c, r_c, u_c, y2_c = xs
            d_s, d_v, d_t = _edge_messages(
                cfg, lp, s, v, t, src_c, dst_c, r_c, u_c, y2_c, n_nodes
            )
            return (a_s + d_s, a_v + d_v, a_t + d_t), None

        C = cfg.channels
        init = (
            jnp.zeros((n_nodes, C), s.dtype),
            jnp.zeros((n_nodes, C, 3), s.dtype),
            jnp.zeros((n_nodes, C, 3, 3), s.dtype),
        )
        resh = lambda x: x.reshape(n_edge_chunks, ce, *x.shape[1:])
        (agg_s, agg_v, agg_t), _ = jax.lax.scan(
            chunk, init, (resh(src), resh(dst), resh(r), resh(u), resh(y2))
        )

    # --- self-interaction + gate -------------------------------------------
    s_new = s @ lp["mix_s_self"] + agg_s @ lp["mix_s_msg"]
    v_new = jnp.einsum("nci,cd->ndi", v, lp["mix_v_self"]) + jnp.einsum(
        "nci,cd->ndi", agg_v, lp["mix_v_msg"]
    )
    t_new = jnp.einsum("ncij,cd->ndij", t, lp["mix_t_self"]) + jnp.einsum(
        "ncij,cd->ndij", agg_t, lp["mix_t_msg"]
    )

    gate_v = jax.nn.sigmoid(s_new @ lp["gate_v"])
    gate_t = jax.nn.sigmoid(s_new @ lp["gate_t"])
    s_out = s + jax.nn.silu(s_new)
    v_out = v + v_new * gate_v[..., None]
    t_out = t + t_new * gate_t[..., None, None]
    return s_out, v_out, t_out


def forward_energy(
    cfg: NequIPConfig, params, node_feat, edge_index, edge_vec, graph_id,
    n_graphs: int, n_edge_chunks: int = 1,
):
    """Per-graph energies.

    node_feat: f32[N, F]; edge_index: int32[2, E] (src, dst);
    edge_vec: f32[E, 3]; graph_id: int32[N].
    """
    N = node_feat.shape[0]
    C = cfg.channels
    s = node_feat @ params["embed_in"]
    v = jnp.zeros((N, C, 3), s.dtype)
    t = jnp.zeros((N, C, 3, 3), s.dtype)

    r, u, y2 = edge_harmonics(edge_vec)
    for lp in params["layers"]:
        s, v, t = _message_layer(
            cfg, lp, s, v, t, edge_index, r, u, y2, N,
            n_edge_chunks=n_edge_chunks,
        )

    node_e = mlp(
        s,
        [params["readout_w1"], params["readout_w2"]],
        [params["readout_b1"], params["readout_b2"]],
        act=jax.nn.silu,
    )[..., 0]
    return jax.ops.segment_sum(node_e, graph_id, num_segments=n_graphs)


def forward_train(cfg: NequIPConfig, params, batch, n_graphs: int,
                  n_edge_chunks: int = 1):
    """MSE energy loss."""
    energies = forward_energy(
        cfg, params, batch["node_feat"], batch["edge_index"], batch["edge_vec"],
        batch["graph_id"], n_graphs, n_edge_chunks=n_edge_chunks,
    )
    return jnp.mean((energies - batch["energy"]) ** 2)


# ===========================================================================
# Partitioned message passing (distributed-GNN halo exchange)
# ===========================================================================
#
# Under pjit, segment_sum from globally-sharded edges into globally-sharded
# nodes makes GSPMD all-reduce full node aggregates every layer, and edge
# gathers all-gather the node features — ~34 GB/device of collectives for
# ogb_products (the baseline dry-run).  The standard distributed-GNN fix
# (DistDGL / Quiver): the data pipeline partitions nodes into per-device
# blocks and groups edges by destination block; then
#   * the destination scatter is device-local (zero collectives),
#   * remote sources are imported once per layer through a fixed-size
#     *halo*: every device exports the features of its nodes that other
#     devices reference (export_idx, a pipeline artifact), one all-gather
#     makes them visible everywhere.
# Edge sources index the concatenation [local nodes | gathered halo].
# Collective bytes per layer = |halo| x C x 13 x 4 — a ~13x cut at a 1/8
# halo fraction (EXPERIMENTS.md Section Perf, cell 3).


def partitioned_train_step_fn(cfg: NequIPConfig, mesh, axes_all, n_graphs: int,
                              n_edge_chunks: int = 1):
    """Returns loss_fn(params, batch) where batch arrays are pre-partitioned:

    node_feat [N, F]   P(all): node blocks per device
    edge_src  [E]      P(all): local-or-halo index (see above)
    edge_dst  [E]      P(all): local destination index
    edge_vec  [E, 3]   P(all)
    export_idx [Xtot]  P(all): per-device export lists (local indices)
    graph_id  [N]      P(all): global graph ids
    energy    [G]      replicated
    """
    from jax.sharding import PartitionSpec as P

    ndev = mesh.size
    aspec = axes_all if len(axes_all) > 1 else axes_all[0]

    def halo_gather(x, export_idx):
        ex = x[export_idx]                       # [X, ...]
        g = jax.lax.all_gather(ex, axes_all, axis=0, tiled=True)  # [ndev*X, ...]
        return g

    def loss_local(params, node_feat, src, dst, evec, export_idx, gid, energy):
        N_loc = node_feat.shape[0]
        C = cfg.channels
        s = node_feat @ params["embed_in"]
        v = jnp.zeros((N_loc, C, 3), s.dtype)
        t = jnp.zeros((N_loc, C, 3, 3), s.dtype)
        r, u, y2 = edge_harmonics(evec)

        E_loc = src.shape[0]
        ce = E_loc // max(n_edge_chunks, 1)

        for li, lp in enumerate(params["layers"]):
            ts_ = jnp.concatenate([s, halo_gather(s, export_idx)], axis=0)
            if li == 0:
                # v and t are structurally zero before the first interaction
                # block: their halos need no exchange (12/13 of the halo
                # bytes of one layer saved)
                X = ts_.shape[0] - s.shape[0]
                tv_ = jnp.concatenate([v, jnp.zeros((X, C, 3), s.dtype)], axis=0)
                tt_ = jnp.concatenate([t, jnp.zeros((X, C, 3, 3), s.dtype)], axis=0)
            else:
                tv_ = jnp.concatenate([v, halo_gather(v, export_idx)], axis=0)
                tt_ = jnp.concatenate([t, halo_gather(t, export_idx)], axis=0)
            if n_edge_chunks <= 1:
                agg_s, agg_v, agg_t = _edge_messages(
                    cfg, lp, ts_, tv_, tt_, src, dst, r, u, y2, N_loc
                )
            else:
                def chunk(carry, xs, lp=lp, ts_=ts_, tv_=tv_, tt_=tt_):
                    a_s, a_v, a_t = carry
                    sc, dc, rc, uc, yc = xs
                    d_s, d_v, d_t = _edge_messages(
                        cfg, lp, ts_, tv_, tt_, sc, dc, rc, uc, yc, N_loc
                    )
                    return (a_s + d_s, a_v + d_v, a_t + d_t), None

                resh = lambda x: x.reshape(n_edge_chunks, ce, *x.shape[1:])
                init = (
                    jnp.zeros((N_loc, C), s.dtype),
                    jnp.zeros((N_loc, C, 3), s.dtype),
                    jnp.zeros((N_loc, C, 3, 3), s.dtype),
                )
                (agg_s, agg_v, agg_t), _ = jax.lax.scan(
                    chunk, init, (resh(src), resh(dst), resh(r), resh(u), resh(y2))
                )
            # self-interaction + gate (identical to the dense layer)
            s_new = s @ lp["mix_s_self"] + agg_s @ lp["mix_s_msg"]
            v_new = jnp.einsum("nci,cd->ndi", v, lp["mix_v_self"]) + jnp.einsum(
                "nci,cd->ndi", agg_v, lp["mix_v_msg"]
            )
            t_new = jnp.einsum("ncij,cd->ndij", t, lp["mix_t_self"]) + jnp.einsum(
                "ncij,cd->ndij", agg_t, lp["mix_t_msg"]
            )
            gate_v = jax.nn.sigmoid(s_new @ lp["gate_v"])
            gate_t = jax.nn.sigmoid(s_new @ lp["gate_t"])
            s = s + jax.nn.silu(s_new)
            v = v + v_new * gate_v[..., None]
            t = t + t_new * gate_t[..., None, None]

        node_e = mlp(
            s,
            [params["readout_w1"], params["readout_w2"]],
            [params["readout_b1"], params["readout_b2"]],
            act=jax.nn.silu,
        )[..., 0]
        e_part = jax.ops.segment_sum(node_e, gid, num_segments=n_graphs)
        e = jax.lax.psum(e_part, axes_all)
        return jnp.mean((e - energy) ** 2)

    from repro.dist.sharding import shard_map_compat

    P_ = P
    shard = shard_map_compat(
        loss_local,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: P_(), jax.eval_shape(
                lambda: init_params(cfg, jax.random.PRNGKey(0)))),
            P_(aspec, None), P_(aspec), P_(aspec), P_(aspec, None),
            P_(aspec), P_(aspec), P_(),
        ),
        out_specs=P_(),
        check_vma=False,
    )

    def loss_fn(params, batch):
        return shard(
            params, batch["node_feat"], batch["edge_src"], batch["edge_dst"],
            batch["edge_vec"], batch["export_idx"], batch["graph_id"],
            batch["energy"],
        )

    return loss_fn


def build_partition(node_feat, edge_index, edge_vec, graph_id, ndev: int,
                    halo: int | None = None):
    """Host-side reference partitioner (tests + small runs): block-partition
    nodes, group edges by destination block (padding with self-loops to
    equal counts), build per-device export lists (padded), and remap edge
    sources to [local | halo-table] indices.

    Returns the batch dict partitioned_train_step_fn expects, as *global*
    arrays laid out so that P(axes) sharding gives each device its block.
    """
    import numpy as np

    N = node_feat.shape[0]
    E = edge_index.shape[1]
    assert N % ndev == 0
    nloc = N // ndev
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    owner = dst // nloc

    # per-device edge lists (pad with self-loop edges on node 0 of the block)
    per_dev_edges = [np.flatnonzero(owner == d) for d in range(ndev)]
    emax = max(1, max(len(x) for x in per_dev_edges))
    # per-device export lists: nodes this device owns that appear as src of
    # edges owned by OTHER devices
    exports = []
    for d in range(ndev):
        mask = (src // nloc == d) & (owner != d)
        exports.append(np.unique(src[mask]) - d * nloc)
    xmax = max(1, max(len(x) for x in exports))
    export_idx = np.zeros((ndev, xmax), np.int32)
    for d, ex in enumerate(exports):
        export_idx[d, : len(ex)] = ex
        # pad with 0 (harmless duplicate export)

    # halo table layout after all_gather: [ndev * xmax] rows; row of global
    # node g owned by device d at export position p -> halo index d*xmax+p
    halo_pos = {}
    for d in range(ndev):
        for p, local in enumerate(exports[d]):
            halo_pos[d * nloc + int(local)] = d * xmax + p

    e_src = np.zeros((ndev, emax), np.int32)
    e_dst = np.zeros((ndev, emax), np.int32)
    e_vec = np.zeros((ndev, emax, 3), np.float32)
    for d in range(ndev):
        idx = per_dev_edges[d]
        for j, e in enumerate(idx):
            sg, dg = int(src[e]), int(dst[e])
            if sg // nloc == d:
                e_src[d, j] = sg - d * nloc
            else:
                e_src[d, j] = nloc + halo_pos[sg]
            e_dst[d, j] = dg - d * nloc
            e_vec[d, j] = edge_vec[e]
        # padding edges scatter to dst = nloc (out of range) — segment_sum
        # with num_segments = nloc drops them, so padding never perturbs
        # real aggregates
        for j in range(len(idx), emax):
            e_src[d, j] = 0
            e_dst[d, j] = nloc
            e_vec[d, j] = (1e-3, 0, 0)

    return {
        "node_feat": np.asarray(node_feat, np.float32),
        "edge_src": e_src.reshape(-1),
        "edge_dst": e_dst.reshape(-1),
        "edge_vec": e_vec.reshape(-1, 3),
        "export_idx": export_idx.reshape(-1),
        "graph_id": np.asarray(graph_id, np.int32),
    }
