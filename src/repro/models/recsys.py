"""RecSys architectures: FM, SASRec, AutoInt, DLRM-MLPerf.

Shared substrate: one concatenated embedding matrix per model, row-sharded
over the ``model`` mesh axis (the tables are the dominant state — DLRM's
MLPerf tables are ~188M rows x 128).  Lookup is ``jnp.take``; multi-hot
bags reduce with ``jax.ops.segment_sum`` (or the fused Pallas kernel,
repro.kernels.embedding_bag).  JAX has no EmbeddingBag — this module *is*
that layer, as the assignment requires.

Steps per arch (wired up in repro.launch.steps):
  train_step      — logloss (FM/AutoInt/DLRM) or BCE-with-negatives (SASRec)
  serve_step      — score a batch of requests (serve_p99 / serve_bulk)
  retrieval_step  — one query vs n_candidates (retrieval_cand): the
                    candidate-varying field re-embeds; everything else is
                    computed once and broadcast.  For FM/SASRec this is a
                    single [n_cand, D] @ [D] matvec — the same "score one
                    pattern against a million stored documents" shape as
                    the paper's top-k retrieval, which is why the paper's
                    index plugs in as a candidate store (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.common import mlp

# MLPerf DLRM (Criteo 1TB) per-table row counts
MLPERF_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def _criteo_like_sizes(n_fields: int, target_total: int = 10_000_000):
    """Synthetic per-field vocab sizes with a realistic skew."""
    base = [3, 10, 60, 250, 1000, 5000, 20_000, 100_000, 500_000, 2_000_000]
    sizes = [base[i % len(base)] for i in range(n_fields)]
    scale = target_total / sum(sizes)
    return tuple(max(3, int(s * scale)) for s in sizes)


def _field_offsets(sizes: Sequence[int]):
    off = [0]
    for s in sizes:
        off.append(off[-1] + s)
    return jnp.asarray(off[:-1], jnp.int32), off[-1]


def _embed_init(key, rows, dim, dtype, scale=0.01):
    """Large tables pad their row count to a multiple of 1024 so row-wise
    sharding divides evenly on both production meshes (512 chips max);
    padding rows are never indexed."""
    if rows >= (1 << 16):
        rows = -(-rows // 1024) * 1024
    return (jax.random.normal(key, (rows, dim)) * scale).astype(dtype)


# ===========================================================================
# FM — Rendle ICDM'10.  O(nk) sum-square trick.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_sizes: tuple = ()
    param_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if not self.vocab_sizes:
            object.__setattr__(
                self, "vocab_sizes", _criteo_like_sizes(self.n_sparse)
            )


def fm_init(cfg: FMConfig, key):
    k1, k2 = jax.random.split(key)
    _, total = _field_offsets(cfg.vocab_sizes)
    return {
        "emb": _embed_init(k1, total, cfg.embed_dim, cfg.param_dtype),
        "lin": _embed_init(k2, total, 1, cfg.param_dtype),
        "bias": jnp.zeros((), cfg.param_dtype),
    }


def fm_logits(cfg: FMConfig, params, sparse_ids):
    """sparse_ids int32[B, F] (per-field local ids)."""
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    gids = sparse_ids + offsets[None, :]
    ve = jnp.take(params["emb"], gids, axis=0)            # [B, F, D]
    le = jnp.take(params["lin"], gids, axis=0)[..., 0]    # [B, F]
    s = ve.sum(axis=1)                                    # [B, D]
    pair = 0.5 * ((s * s).sum(-1) - (ve * ve).sum((-1, -2)))
    return params["bias"] + le.sum(-1) + pair


def fm_train_loss(cfg, params, batch):
    logits = fm_logits(cfg, params, batch["sparse"])
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def fm_retrieval(cfg: FMConfig, params, user_sparse, cand_ids, cand_field: int = 0):
    """Score one user against candidates filling field ``cand_field``."""
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    F = cfg.n_sparse
    user_fields = jnp.asarray([f for f in range(F) if f != cand_field], jnp.int32)
    ug = user_sparse[user_fields] + offsets[user_fields]
    uv = jnp.take(params["emb"], ug, axis=0)              # [F-1, D]
    ul = jnp.take(params["lin"], ug, axis=0)[..., 0]
    s_user = uv.sum(0)
    const = (
        params["bias"]
        + ul.sum()
        + 0.5 * ((s_user * s_user).sum() - (uv * uv).sum())
    )
    cg = cand_ids + offsets[cand_field]
    cv = jnp.take(params["emb"], cg, axis=0)              # [Ncand, D]
    cl = jnp.take(params["lin"], cg, axis=0)[..., 0]
    return const + cl + cv @ s_user


# ===========================================================================
# SASRec — self-attentive sequential recommendation (arXiv:1808.09781)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    param_dtype: jnp.dtype = jnp.float32


def sasrec_init(cfg: SASRecConfig, key):
    keys = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    D = cfg.embed_dim
    p = {
        "item_emb": _embed_init(keys[0], cfg.n_items + 1, D, cfg.param_dtype, 0.02),
        "pos_emb": _embed_init(keys[1], cfg.seq_len, D, cfg.param_dtype, 0.02),
        "blocks": [],
    }
    for b in range(cfg.n_blocks):
        bk = jax.random.split(keys[2 + b], 6)
        p["blocks"].append(
            {
                "wq": _embed_init(bk[0], D, D, cfg.param_dtype, D**-0.5),
                "wk": _embed_init(bk[1], D, D, cfg.param_dtype, D**-0.5),
                "wv": _embed_init(bk[2], D, D, cfg.param_dtype, D**-0.5),
                "w1": _embed_init(bk[3], D, D, cfg.param_dtype, D**-0.5),
                "b1": jnp.zeros((D,), cfg.param_dtype),
                "w2": _embed_init(bk[4], D, D, cfg.param_dtype, D**-0.5),
                "b2": jnp.zeros((D,), cfg.param_dtype),
                "ln1": jnp.ones((D,), cfg.param_dtype),
                "ln2": jnp.ones((D,), cfg.param_dtype),
            }
        )
    return p


def _ln(x, g):
    m = x.mean(-1, keepdims=True)
    v = ((x - m) ** 2).mean(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-6) * g


def sasrec_encode(cfg: SASRecConfig, params, item_seq):
    """item_seq int32[B, S] (0 = padding) -> hidden states [B, S, D]."""
    B, S = item_seq.shape
    x = jnp.take(params["item_emb"], item_seq, axis=0)
    x = x + params["pos_emb"][None, :S]
    mask = (item_seq > 0)[:, None, None, :]               # key mask
    causal = jnp.tril(jnp.ones((S, S), bool))[None, None]
    H = cfg.n_heads
    Dh = cfg.embed_dim // H
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        k = (h @ blk["wk"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        v = (h @ blk["wv"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (Dh**-0.5)
        logits = jnp.where(causal & mask, logits, -1e30)
        attn = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", attn, v).transpose(0, 2, 1, 3)
        x = x + o.reshape(B, S, cfg.embed_dim)
        h = _ln(x, blk["ln2"])
        x = x + jax.nn.relu(h @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
    return x


def sasrec_train_loss(cfg, params, batch):
    """BCE over (positive next item, sampled negative) at each position."""
    seq = batch["item_seq"]                               # [B, S]
    pos = batch["pos_items"]                              # [B, S]
    neg = batch["neg_items"]                              # [B, S]
    h = sasrec_encode(cfg, params, seq)                   # [B, S, D]
    pe = jnp.take(params["item_emb"], pos, axis=0)
    ne = jnp.take(params["item_emb"], neg, axis=0)
    pos_score = (h * pe).sum(-1)
    neg_score = (h * ne).sum(-1)
    mask = (pos > 0).astype(jnp.float32)
    loss = -(
        jax.nn.log_sigmoid(pos_score) + jax.nn.log_sigmoid(-neg_score)
    ) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)


def sasrec_serve(cfg, params, batch):
    """Score (sequence, target) pairs."""
    h = sasrec_encode(cfg, params, batch["item_seq"])[:, -1]
    te = jnp.take(params["item_emb"], batch["target"], axis=0)
    return (h * te).sum(-1)


def sasrec_retrieval(cfg, params, item_seq, cand_ids):
    """One sequence vs n_candidates: final state . candidate embeddings."""
    h = sasrec_encode(cfg, params, item_seq)[:, -1][0]    # [D]
    ce = jnp.take(params["item_emb"], cand_ids, axis=0)   # [N, D]
    return ce @ h


# ===========================================================================
# AutoInt — attention-based feature interaction (arXiv:1810.11921)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocab_sizes: tuple = ()
    param_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if not self.vocab_sizes:
            object.__setattr__(
                self, "vocab_sizes", _criteo_like_sizes(self.n_sparse)
            )


def autoint_init(cfg: AutoIntConfig, key):
    keys = jax.random.split(key, 3 + cfg.n_attn_layers)
    _, total = _field_offsets(cfg.vocab_sizes)
    din = cfg.embed_dim
    p = {"emb": _embed_init(keys[0], total, din, cfg.param_dtype), "layers": []}
    d = din
    for i in range(cfg.n_attn_layers):
        lk = jax.random.split(keys[1 + i], 4)
        p["layers"].append(
            {
                "wq": _embed_init(lk[0], d, cfg.d_attn, cfg.param_dtype, d**-0.5),
                "wk": _embed_init(lk[1], d, cfg.d_attn, cfg.param_dtype, d**-0.5),
                "wv": _embed_init(lk[2], d, cfg.d_attn, cfg.param_dtype, d**-0.5),
                "wres": _embed_init(lk[3], d, cfg.d_attn, cfg.param_dtype, d**-0.5),
            }
        )
        d = cfg.d_attn
    p["out_w"] = _embed_init(keys[-1], cfg.n_sparse * d, 1, cfg.param_dtype)
    p["out_b"] = jnp.zeros((), cfg.param_dtype)
    return p


def autoint_logits(cfg: AutoIntConfig, params, sparse_ids):
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    gids = sparse_ids + offsets[None, :]
    x = jnp.take(params["emb"], gids, axis=0)             # [B, F, D]
    return _autoint_attend(cfg, params, x)


def _autoint_attend(cfg: AutoIntConfig, params, x):
    H = cfg.n_heads
    for lp in params["layers"]:
        dh = cfg.d_attn // H
        q = (x @ lp["wq"]).reshape(*x.shape[:-1], H, dh)
        k = (x @ lp["wk"]).reshape(*x.shape[:-1], H, dh)
        v = (x @ lp["wv"]).reshape(*x.shape[:-1], H, dh)
        logits = jnp.einsum("bfhd,bghd->bhfg", q, k) * (dh**-0.5)
        attn = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", attn, v).reshape(
            *x.shape[:-1], cfg.d_attn
        )
        x = jax.nn.relu(o + x @ lp["wres"])
    flat = x.reshape(x.shape[0], -1)
    return (flat @ params["out_w"])[..., 0] + params["out_b"]


def autoint_train_loss(cfg, params, batch):
    logits = autoint_logits(cfg, params, batch["sparse"])
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def autoint_retrieval(cfg, params, user_sparse, cand_ids, cand_field: int = 0):
    """Bulk-score candidates by swapping one field's id.

    Same gather restructure as dlrm_retrieval: constant user rows are
    embedded once; only the candidate field's rows move per candidate."""
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    n = cand_ids.shape[0]
    gids = user_sparse + offsets
    ue = jnp.take(params["emb"], gids, axis=0)                # [F, D]
    ce = jnp.take(params["emb"], cand_ids + offsets[cand_field], axis=0)
    x = jnp.broadcast_to(ue[None], (n, cfg.n_sparse, cfg.embed_dim))
    x = jnp.concatenate(
        [x[:, :cand_field], ce[:, None], x[:, cand_field + 1 :]], axis=1
    )
    return _autoint_attend(cfg, params, x)


# ===========================================================================
# DLRM — MLPerf config (arXiv:1906.00091)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    vocab_sizes: tuple = MLPERF_TABLE_SIZES
    param_dtype: jnp.dtype = jnp.float32


def dlrm_init(cfg: DLRMConfig, key):
    keys = jax.random.split(key, 3)
    _, total = _field_offsets(cfg.vocab_sizes)
    p = {"emb": _embed_init(keys[0], total, cfg.embed_dim, cfg.param_dtype)}

    def mlp_params(k, dims):
        ws, bs = [], []
        kk = jax.random.split(k, len(dims) - 1)
        for i in range(len(dims) - 1):
            ws.append(_embed_init(kk[i], dims[i], dims[i + 1], cfg.param_dtype,
                                  dims[i] ** -0.5))
            bs.append(jnp.zeros((dims[i + 1],), cfg.param_dtype))
        return ws, bs

    p["bot_w"], p["bot_b"] = mlp_params(keys[1], (cfg.n_dense, *cfg.bot_mlp))
    n_feat = cfg.n_sparse + 1
    d_inter = n_feat * (n_feat - 1) // 2 + cfg.bot_mlp[-1]
    p["top_w"], p["top_b"] = mlp_params(keys[2], (d_inter, *cfg.top_mlp))
    return p


def _dot_interaction(z):
    """z [B, F, D] -> upper-triangle pairwise dots [B, F(F-1)/2]."""
    B, F, D = z.shape
    zz = jnp.einsum("bfd,bgd->bfg", z, z)
    iu, ju = jnp.triu_indices(F, k=1)
    return zz[:, iu, ju]


def dlrm_logits(cfg: DLRMConfig, params, dense, sparse_ids):
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    bot = mlp(dense, params["bot_w"], params["bot_b"])    # [B, 128]
    gids = sparse_ids + offsets[None, :]
    emb = jnp.take(params["emb"], gids, axis=0)           # [B, 26, 128]
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)   # [B, 27, 128]
    inter = _dot_interaction(z)
    top_in = jnp.concatenate([bot, inter], axis=-1)
    return mlp(top_in, params["top_w"], params["top_b"])[..., 0]


def dlrm_train_loss(cfg, params, batch):
    logits = dlrm_logits(cfg, params, batch["dense"], batch["sparse"])
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def dlrm_retrieval(cfg, params, dense, user_sparse, cand_ids, cand_field: int = 0,
                   constrain=None):
    """Score one user against candidates varying one sparse field.

    The naive path (broadcast the user's ids to [n_cand, 26] and run
    dlrm_logits) makes GSPMD exchange [n_cand, 26, D] of gathered rows over
    the row-sharded table even though 25 of the 26 rows are the same for
    every candidate.  Here the constant rows are gathered once and only the
    candidate field's [n_cand, D] rows move — a ~26x cut in collective
    bytes on the production mesh (EXPERIMENTS.md Section Perf, cell 1).
    """
    offsets, _ = _field_offsets(cfg.vocab_sizes)
    n = cand_ids.shape[0]
    # serving numerics: the interaction runs in the table dtype (the cell
    # registry serves the big tables in bf16, halving the bytes of the
    # cross-device row exchange — no f32 consumer near the gather means
    # the masked-partial-sum all-reduce stays bf16); top MLP in f32.
    tdt = params["emb"].dtype
    bot = mlp(dense[None, :], params["bot_w"], params["bot_b"])[0].astype(tdt)
    user_fields = jnp.asarray(
        [f for f in range(cfg.n_sparse) if f != cand_field], jnp.int32
    )
    ug = user_sparse[user_fields] + offsets[user_fields]
    ue = jnp.take(params["emb"], ug, axis=0)                           # [25, D]
    ce = jnp.take(params["emb"], cand_ids + offsets[cand_field], axis=0)
    if constrain is not None:
        # pin the gathered rows to candidate sharding (GSPMD may then
        # reduce-scatter the masked gather instead of all-reducing)
        ce = constrain(ce)

    # assemble z rows in canonical order: [bot, field_0, ..., field_25]
    before = ue[:cand_field]
    after = ue[cand_field:]
    zc_head = jnp.concatenate([bot[None], before], axis=0)             # const
    n_head = zc_head.shape[0]
    z = jnp.concatenate(
        [
            jnp.broadcast_to(zc_head[None], (n, n_head, cfg.embed_dim)),
            ce[:, None, :],
            jnp.broadcast_to(after[None], (n, after.shape[0], cfg.embed_dim)),
        ],
        axis=1,
    )                                                                   # [n, 27, D]
    inter = _dot_interaction(z).astype(jnp.float32)
    top_in = jnp.concatenate(
        [jnp.broadcast_to(bot[None].astype(jnp.float32), (n, cfg.bot_mlp[-1])),
         inter], axis=-1,
    )
    return mlp(top_in, params["top_w"], params["top_b"])[..., 0]
