"""smollm-135m: dense 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M]"""
from repro.models.transformer import LMConfig

ARCH_ID = "smollm-135m"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        d_ff=1536, vocab=49152, tie_embeddings=True,
    )


def reduced_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, tie_embeddings=True,
        param_dtype=jnp.float32, act_dtype=jnp.float32,
    )
