"""Cell registry: (architecture x input-shape) -> CellSpec.

A *cell* is one entry of the 40-cell dry-run/roofline matrix: a step
function (train / prefill / decode / serve / retrieval), abstract
ShapeDtypeStruct inputs (never allocated), partition specs for the given
mesh, and roofline metadata (analytic FLOPs/bytes models + scan trip
multipliers for HLO collective accounting).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    MeshAxes,
    axes_for_mesh,
    dp_size,
    lm_batch_specs,
    lm_cache_specs,
    lm_param_specs,
    nequip_batch_specs,
    opt_state_specs,
    recsys_param_specs,
)
from repro.models import nequip as nequip_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import AdamWConfig, abstract_opt_state, adamw_update

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "smollm-135m": "repro.configs.smollm_135m",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "nequip": "repro.configs.nequip",
    "fm": "repro.configs.fm",
    "sasrec": "repro.configs.sasrec",
    "autoint": "repro.configs.autoint",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
}

ALL_ARCHS = tuple(_ARCH_MODULES)

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# GNN shapes: sizes padded up to multiples of 512 (and of 512 * edge_chunks)
# so every array dim shards evenly on both meshes; padding is a data-
# pipeline responsibility (dummy isolated nodes / self-loop edges).
GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="train", n_nodes=2708, n_edges=10556, d_feat=1433,
        n_graphs=1, edge_chunks=1, shard=False,
    ),
    "minibatch_lg": dict(
        kind="train", n_nodes=169_984, n_edges=169_984, d_feat=602,
        n_graphs=1, edge_chunks=4, shard=True, partitioned=True,
        note="1024 seeds x fanout 15-10, padded from 168,960 edges",
    ),
    "ogb_products": dict(
        kind="train", n_nodes=2_449_408, n_edges=61_865_984, d_feat=100,
        n_graphs=1, edge_chunks=8, shard=True, partitioned=True,
        note="padded from 2,449,029 nodes / 61,859,140 edges",
    ),
    "molecule": dict(
        kind="train", n_nodes=3840, n_edges=8192, d_feat=32,
        n_graphs=128, edge_chunks=1, shard=True,
    ),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

ARCH_SHAPES = {
    arch: (
        tuple(LM_SHAPES)
        if importlib.import_module(m).FAMILY == "lm"
        else tuple(GNN_SHAPES)
        if importlib.import_module(m).FAMILY == "gnn"
        else tuple(RECSYS_SHAPES)
    )
    for arch, m in _ARCH_MODULES.items()
}


def get_arch_module(arch_id: str):
    return importlib.import_module(_ARCH_MODULES[arch_id])


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    abstract_args: tuple
    in_specs: tuple
    out_specs: Any
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _maybe_axes(n: int, mesh, axes_tuple):
    """The largest prefix of axes whose product divides n (else None)."""
    prod = 1
    usable = []
    for a in axes_tuple:
        prod *= mesh.shape[a]
        if n % prod == 0:
            usable.append(a)
        else:
            break
    if not usable:
        return None
    return tuple(usable) if len(usable) > 1 else usable[0]


# ===========================================================================
# LM cells
# ===========================================================================


def _lm_attn_flops_per_layer_fwd(cfg, B, S, local: bool):
    s_eff = min(cfg.local_chunk, S) if local else S
    return 4.0 * B * S * s_eff * cfg.n_heads * cfg.head_dim


def _lm_meta(cfg: tf_mod.LMConfig, kind: str, B: int, S: int):
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    T = B * S
    n_local = len(cfg.local_positions) * cfg.n_groups
    n_global = cfg.n_layers - n_local
    attn_fwd = n_local * _lm_attn_flops_per_layer_fwd(cfg, B, S, True) + (
        n_global * _lm_attn_flops_per_layer_fwd(cfg, B, S, False)
    )
    wb = jnp.dtype(cfg.param_dtype).itemsize
    cache_bytes = (
        cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2  # bf16 k+v
    )
    if kind == "train":
        model_flops = 6.0 * n_act * T
        # fwd + bwd + full-remat recompute = 4x fwd matmul flops
        analytic_flops = 8.0 * n_act * T + 4.0 * attn_fwd
        analytic_bytes = (
            n_tot * (wb * 2 + 4 + 4)        # params r/w, grad, opt moments
            + T * cfg.d_model * cfg.n_layers * 12 * 2  # activation traffic
        )
    elif kind == "prefill":
        model_flops = 2.0 * n_act * T
        analytic_flops = 2.0 * n_act * T + attn_fwd
        analytic_bytes = n_tot * wb + cache_bytes + T * cfg.d_model * cfg.n_layers * 6 * 2
    else:  # decode
        model_flops = 2.0 * n_act * B
        # decode MoE computes all experts for the live tokens
        n_dec = n_tot if cfg.moe else n_act
        attn_dec = 4.0 * B * S * cfg.n_heads * cfg.head_dim * cfg.n_layers
        model_flops = 2.0 * n_act * B
        analytic_flops = 2.0 * n_dec * B + attn_dec
        analytic_bytes = n_dec * wb + cache_bytes
    return dict(
        model_flops=float(model_flops),
        analytic_flops=float(analytic_flops),
        analytic_bytes=float(analytic_bytes),
        scan_trips=cfg.n_groups,
        params_total=n_tot,
        params_active=n_act,
        tokens=T if kind != "decode" else B,
    )


def _lm_cell(arch_id, mod, shape_id, mesh, reduced):
    cfg = mod.reduced_config() if reduced else mod.config()
    axes = axes_for_mesh(mesh)
    info = LM_SHAPES[shape_id]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]

    opt_dtype = jnp.bfloat16 if getattr(mod, "OPT_MOMENT_DTYPE", "") == "bfloat16" else jnp.float32
    opt_cfg = AdamWConfig(moment_dtype=opt_dtype)

    params_abs = tf_mod.abstract_params(cfg)
    pspecs = lm_param_specs(cfg, axes, mesh, params_abs)

    # FSDP / 2-D TP: when TP-only sharding leaves more than ~2 GB of
    # parameters per device, shard every weight over the data axes too
    # (expert weights all-gather inside the shard_map EP block; dense
    # weights get GSPMD-inserted gathers or partial-sum matmuls).
    from repro.dist.sharding import zero_spec_for

    wb = jnp.dtype(cfg.param_dtype).itemsize
    mdl_size = mesh.shape[axes.mdl]
    needs_fsdp = cfg.param_count() * wb / mdl_size > 2 * 2**30
    if needs_fsdp:
        dpn = dp_size(mesh, axes)

        def extend(path, spec, ab):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name == "router":      # stays replicated for shard_map EP
                return spec
            return zero_spec_for(spec, ab.shape, axes, dpn)

        pspecs = jax.tree_util.tree_map_with_path(
            extend, pspecs, params_abs,
            is_leaf=lambda x: isinstance(x, P),
        )

    # attach the mesh for sharding constraints (sequence-parallel residual
    # carries, context-parallel attention) and shard_map EP on MoE archs
    if kind in ("train", "prefill"):
        cfg = dataclasses.replace(
            cfg, ep_mesh=mesh, ep_dp_axes=tuple(axes.dp), ep_fsdp=needs_fsdp
        )

    if kind == "train":
        opt_abs = abstract_opt_state(params_abs, opt_cfg)
        ospecs = opt_state_specs(pspecs, params_abs, axes, dp_size(mesh, axes))
        batch_abs = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        bspecs = lm_batch_specs(axes)

        def step(params, opt_state, batch):
            def loss_fn(p):
                return tf_mod.forward_train(cfg, p, batch["tokens"], batch["labels"])

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
            return new_params, new_opt, loss

        return CellSpec(
            arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            meta=_lm_meta(cfg, kind, B, S),
        )

    if kind == "prefill":
        tokens_abs = _sds((B, S), jnp.int32)
        cspecs = lm_cache_specs(cfg, axes, B, mesh)

        def step(params, tokens):
            return tf_mod.forward_prefill(cfg, params, tokens)

        logits_spec = P(_maybe_axes(B, mesh, axes.dp), axes.mdl)
        return CellSpec(
            arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
            abstract_args=(params_abs, tokens_abs),
            in_specs=(pspecs, P(axes.dp, None)),
            out_specs=(logits_spec, cspecs),
            meta=_lm_meta(cfg, kind, B, S),
        )

    # decode
    cache_abs = tf_mod.abstract_cache(cfg, B, S)
    cspecs = lm_cache_specs(cfg, axes, B, mesh)
    token_spec = P(_maybe_axes(B, mesh, axes.dp))
    logits_spec = P(_maybe_axes(B, mesh, axes.dp), axes.mdl)

    def step(params, token, cache, t):
        return tf_mod.forward_decode(cfg, params, token, cache, t)

    return CellSpec(
        arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
        abstract_args=(
            params_abs, _sds((B,), jnp.int32), cache_abs, _sds((), jnp.int32),
        ),
        in_specs=(pspecs, token_spec, cspecs, P()),
        out_specs=(logits_spec, cspecs),
        meta=_lm_meta(cfg, kind, B, S),
    )


# ===========================================================================
# GNN cells
# ===========================================================================


def _gnn_meta(cfg, info):
    N, E = info["n_nodes"], info["n_edges"]
    C = cfg.channels
    L = cfg.n_layers
    # per edge: radial MLP + ~10 tensor-product paths over (C, <=9) comps
    per_edge = 2 * (cfg.n_rbf * cfg.radial_hidden + cfg.radial_hidden * cfg.n_paths * C) + 140 * C
    # per node: 6 channel mixes over (1 + 3 + 9) components + gates
    per_node = 2 * C * C * 26 + 4 * C * C
    fwd = L * (E * per_edge + N * per_node) + 2 * N * cfg.d_feat_in * C
    model_flops = 3.0 * fwd  # fwd + bwd
    analytic_flops = 4.0 * fwd  # + remat-free but scan recompute margin
    msg_bytes = E * C * 13 * 4  # one chunk pass writes/read messages
    analytic_bytes = L * (2 * msg_bytes + N * C * 13 * 4 * 4)
    return dict(
        model_flops=float(model_flops),
        analytic_flops=float(analytic_flops),
        analytic_bytes=float(analytic_bytes),
        scan_trips=info["edge_chunks"],
        params_total=sum(
            int(np.prod(l.shape))
            for l in jax.tree.leaves(nequip_mod.abstract_params(cfg))
        ),
        params_active=0,
        tokens=N,
    )


def _gnn_cell(arch_id, mod, shape_id, mesh, reduced):
    info = GNN_SHAPES[shape_id]
    axes = axes_for_mesh(mesh)
    if reduced:
        cfg = mod.reduced_config()
        N, E, F, G = 64, 128, cfg.d_feat_in, 4
        chunks = 1
    else:
        cfg = mod.config(d_feat_in=info["d_feat"])
        N, E, F, G = info["n_nodes"], info["n_edges"], info["d_feat"], info["n_graphs"]
        chunks = info["edge_chunks"]

    params_abs = nequip_mod.abstract_params(cfg)
    pspecs = jax.tree.map(lambda _: P(), params_abs)
    opt_cfg = AdamWConfig()
    opt_abs = abstract_opt_state(params_abs, opt_cfg)
    ospecs = jax.tree.map(lambda _: P(), opt_abs)

    partitioned = info.get("partitioned", False) and not reduced
    if partitioned:
        # distributed-GNN layout: nodes/edges pre-partitioned by the data
        # pipeline, fixed-size halo exports (1/8 of the node block)
        ndev = mesh.size
        n_loc = N // ndev
        xmax = max(1, n_loc // 8)
        aspec = axes.all_axes if len(axes.all_axes) > 1 else axes.all_axes[0]
        batch_abs = {
            "node_feat": _sds((N, F), jnp.float32),
            "edge_src": _sds((E,), jnp.int32),
            "edge_dst": _sds((E,), jnp.int32),
            "edge_vec": _sds((E, 3), jnp.float32),
            "export_idx": _sds((ndev * xmax,), jnp.int32),
            "graph_id": _sds((N,), jnp.int32),
            "energy": _sds((G,), jnp.float32),
        }
        bspecs = {
            "node_feat": P(aspec, None),
            "edge_src": P(aspec),
            "edge_dst": P(aspec),
            "edge_vec": P(aspec, None),
            "export_idx": P(aspec),
            "graph_id": P(aspec),
            "energy": P(),
        }
        loss_fn_part = nequip_mod.partitioned_train_step_fn(
            cfg, mesh, axes.all_axes, G, n_edge_chunks=chunks
        )

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn_part)(params, batch)
            new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
            return new_params, new_opt, loss

        return CellSpec(
            arch=arch_id, shape=shape_id, kind="train", step_fn=step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            meta=_gnn_meta(cfg, info),
        )

    batch_abs = {
        "node_feat": _sds((N, F), jnp.float32),
        "edge_index": _sds((2, E), jnp.int32),
        "edge_vec": _sds((E, 3), jnp.float32),
        "graph_id": _sds((N,), jnp.int32),
        "energy": _sds((G,), jnp.float32),
    }
    if info.get("shard", True) and not reduced:
        node_ax = _maybe_axes(N, mesh, axes.all_axes)
        edge_ax = _maybe_axes(E, mesh, axes.all_axes)
        bspecs = {
            "node_feat": P(node_ax, None),
            "edge_index": P(None, edge_ax),
            "edge_vec": P(edge_ax, None),
            "graph_id": P(node_ax),
            "energy": P(),
        }
    else:
        bspecs = jax.tree.map(lambda _: P(), batch_abs)

    def step(params, opt_state, batch):
        def loss_fn(p):
            return nequip_mod.forward_train(cfg, p, batch, G, n_edge_chunks=chunks)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, loss

    return CellSpec(
        arch=arch_id, shape=shape_id, kind="train", step_fn=step,
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, P()),
        meta=_gnn_meta(cfg, info if not reduced else dict(
            n_nodes=N, n_edges=E, edge_chunks=chunks)),
    )


# ===========================================================================
# RecSys cells
# ===========================================================================


def _recsys_flops_fwd(arch_id, cfg, B):
    if arch_id.startswith("fm"):
        return 4.0 * B * cfg.n_sparse * cfg.embed_dim
    if arch_id.startswith("sasrec"):
        S, D = cfg.seq_len, cfg.embed_dim
        per_blk = 8 * S * D * D + 4 * S * S * D
        return B * (cfg.n_blocks * per_blk)
    if arch_id.startswith("autoint"):
        F = cfg.n_sparse
        d = cfg.d_attn
        per_l = 6 * F * cfg.embed_dim * d + 4 * F * F * d
        return B * cfg.n_attn_layers * per_l
    # dlrm
    dims = (cfg.n_dense, *cfg.bot_mlp)
    bot = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    nf = cfg.n_sparse + 1
    inter = 2 * nf * nf * cfg.embed_dim
    d_in = nf * (nf - 1) // 2 + cfg.bot_mlp[-1]
    tdims = (d_in, *cfg.top_mlp)
    top = sum(2 * a * b for a, b in zip(tdims[:-1], tdims[1:]))
    return float(B) * (bot + inter + top)


def _recsys_bytes(arch_id, cfg, B, train: bool):
    lookup = {
        "fm": cfg.n_sparse * (cfg.embed_dim + 1) * 4 if hasattr(cfg, "n_sparse") else 0,
        "sasrec": 3 * getattr(cfg, "seq_len", 0) * getattr(cfg, "embed_dim", 0) * 4,
        "autoint": getattr(cfg, "n_sparse", 0) * getattr(cfg, "embed_dim", 0) * 4,
        "dlrm-mlperf": getattr(cfg, "n_sparse", 0) * getattr(cfg, "embed_dim", 0) * 4,
    }
    key = arch_id.split("-reduced")[0]
    key = key if key in lookup else arch_id
    per_row = lookup.get(key, 64)
    factor = 4 if train else 1   # grads + moments touch the same rows
    return float(B) * per_row * factor


def _recsys_cell(arch_id, mod, shape_id, mesh, reduced):
    info = RECSYS_SHAPES[shape_id]
    axes = axes_for_mesh(mesh)
    cfg = mod.reduced_config() if reduced else mod.config()
    kind = info["kind"]
    B = info["batch"] if not reduced else 8
    fam = arch_id

    init_fn, loss_fn, serve_fn, retr_fn = {
        "fm": (recsys_mod.fm_init, recsys_mod.fm_train_loss, None, recsys_mod.fm_retrieval),
        "sasrec": (recsys_mod.sasrec_init, recsys_mod.sasrec_train_loss,
                   recsys_mod.sasrec_serve, recsys_mod.sasrec_retrieval),
        "autoint": (recsys_mod.autoint_init, recsys_mod.autoint_train_loss,
                    None, recsys_mod.autoint_retrieval),
        "dlrm-mlperf": (recsys_mod.dlrm_init, recsys_mod.dlrm_train_loss,
                        None, recsys_mod.dlrm_retrieval),
    }[fam]

    params_abs = jax.eval_shape(lambda: init_fn(cfg, jax.random.PRNGKey(0)))
    if kind != "train" and not reduced:
        # serving copy of the big tables in bf16: halves row-exchange bytes
        params_abs = jax.tree.map(
            lambda ab: jax.ShapeDtypeStruct(ab.shape, jnp.bfloat16)
            if (ab.ndim == 2 and ab.shape[0] >= (1 << 16))
            else ab,
            params_abs,
        )
    pspecs = recsys_param_specs(params_abs, axes, mesh)

    def batch_for(B):
        if fam == "sasrec":
            return {
                "item_seq": _sds((B, cfg.seq_len), jnp.int32),
                "pos_items": _sds((B, cfg.seq_len), jnp.int32),
                "neg_items": _sds((B, cfg.seq_len), jnp.int32),
                "label": _sds((B,), jnp.float32),
            }
        batch = {
            "sparse": _sds((B, cfg.n_sparse), jnp.int32),
            "label": _sds((B,), jnp.float32),
        }
        if fam == "dlrm-mlperf":
            batch["dense"] = _sds((B, cfg.n_dense), jnp.float32)
        return batch

    meta = dict(
        model_flops=_recsys_flops_fwd(fam, cfg, B) * (3 if kind == "train" else 1),
        analytic_flops=_recsys_flops_fwd(fam, cfg, B) * (3 if kind == "train" else 1),
        analytic_bytes=_recsys_bytes(fam, cfg, B, kind == "train"),
        scan_trips=1,
        params_total=sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_abs)),
        params_active=0,
        tokens=B,
    )

    if kind == "train":
        opt_cfg = AdamWConfig()
        opt_abs = abstract_opt_state(params_abs, opt_cfg)
        ospecs = opt_state_specs(pspecs, params_abs, axes, dp_size(mesh, axes))
        batch_abs = batch_for(B)
        bspecs = {
            k: P(axes.dp) if v.ndim == 1 else P(axes.dp, None)
            for k, v in batch_abs.items()
        }

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
            new_params, new_opt = adamw_update(opt_cfg, params, grads, opt_state)
            return new_params, new_opt, loss

        return CellSpec(
            arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
            abstract_args=(params_abs, opt_abs, batch_abs),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            meta=meta,
        )

    if kind == "serve":
        batch_abs = batch_for(B)
        if fam == "sasrec":
            batch_abs = {
                "item_seq": batch_abs["item_seq"],
                "target": _sds((B,), jnp.int32),
            }

            def step(params, batch):
                return recsys_mod.sasrec_serve(cfg, params, batch)

        else:
            batch_abs.pop("label")
            if fam == "fm":
                def step(params, batch):
                    return recsys_mod.fm_logits(cfg, params, batch["sparse"])
            elif fam == "autoint":
                def step(params, batch):
                    return recsys_mod.autoint_logits(cfg, params, batch["sparse"])
            else:
                def step(params, batch):
                    return recsys_mod.dlrm_logits(cfg, params, batch["dense"], batch["sparse"])

        bspecs = {
            k: P(axes.dp) if v.ndim == 1 else P(axes.dp, None)
            for k, v in batch_abs.items()
        }
        return CellSpec(
            arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
            abstract_args=(params_abs, batch_abs),
            in_specs=(pspecs, bspecs),
            out_specs=P(axes.dp),
            meta=meta,
        )

    # retrieval: one query vs n_candidates
    ncand = info.get("n_candidates", 1000) if not reduced else 64
    cand_abs = _sds((ncand,), jnp.int32)
    cand_spec = P(_maybe_axes(ncand, mesh, axes.all_axes))
    meta = dict(meta)
    meta["model_flops"] = _recsys_flops_fwd(fam, cfg, ncand)
    meta["analytic_flops"] = meta["model_flops"]
    meta["analytic_bytes"] = _recsys_bytes(fam, cfg, ncand, False)
    meta["tokens"] = ncand

    if fam == "sasrec":
        seq_abs = _sds((1, cfg.seq_len), jnp.int32)

        def step(params, item_seq, cand):
            return recsys_mod.sasrec_retrieval(cfg, params, item_seq, cand)

        args = (params_abs, seq_abs, cand_abs)
        ispecs = (pspecs, P(), cand_spec)
    elif fam == "fm":
        user_abs = _sds((cfg.n_sparse,), jnp.int32)

        def step(params, user, cand):
            return recsys_mod.fm_retrieval(cfg, params, user, cand)

        args = (params_abs, user_abs, cand_abs)
        ispecs = (pspecs, P(), cand_spec)
    elif fam == "autoint":
        user_abs = _sds((cfg.n_sparse,), jnp.int32)

        def step(params, user, cand):
            return recsys_mod.autoint_retrieval(cfg, params, user, cand)

        args = (params_abs, user_abs, cand_abs)
        ispecs = (pspecs, P(), cand_spec)
    else:
        user_abs = _sds((cfg.n_sparse,), jnp.int32)
        dense_abs = _sds((cfg.n_dense,), jnp.float32)
        cand_sharding = jax.sharding.NamedSharding(
            mesh, P(_maybe_axes(ncand, mesh, axes.dp), None)
        )

        def step(params, dense, user, cand):
            return recsys_mod.dlrm_retrieval(
                cfg, params, dense, user, cand,
                constrain=lambda x: jax.lax.with_sharding_constraint(
                    x, cand_sharding
                ),
            )

        args = (params_abs, dense_abs, user_abs, cand_abs)
        ispecs = (pspecs, P(), P(), cand_spec)

    return CellSpec(
        arch=arch_id, shape=shape_id, kind=kind, step_fn=step,
        abstract_args=args,
        in_specs=ispecs,
        out_specs=cand_spec,
        meta=meta,
    )


# ===========================================================================
# Entry points
# ===========================================================================


def build_cell(arch_id: str, shape_id: str, mesh, reduced: bool = False) -> CellSpec:
    mod = get_arch_module(arch_id)
    if mod.FAMILY == "lm":
        return _lm_cell(arch_id, mod, shape_id, mesh, reduced)
    if mod.FAMILY == "gnn":
        return _gnn_cell(arch_id, mod, shape_id, mesh, reduced)
    return _recsys_cell(arch_id, mod, shape_id, mesh, reduced)


def all_cells():
    for arch in ALL_ARCHS:
        for shape in ARCH_SHAPES[arch]:
            yield arch, shape
