"""Assigned architecture configs (one module per arch) + cell registry.

``repro.configs.registry`` maps (arch_id, shape_id, mesh) to a CellSpec:
the jit-able step function, abstract (ShapeDtypeStruct) inputs, partition
specs, and roofline metadata.  The dry-run and benchmarks consume cells.
"""

from repro.configs.registry import (
    ALL_ARCHS,
    ARCH_SHAPES,
    CellSpec,
    all_cells,
    build_cell,
    get_arch_module,
)

__all__ = [
    "ALL_ARCHS",
    "ARCH_SHAPES",
    "CellSpec",
    "all_cells",
    "build_cell",
    "get_arch_module",
]
