"""fm: factorization machine, 39 sparse fields, embed_dim=10, 2-way
interactions via the O(nk) sum-square trick.  [Rendle ICDM'10]"""
from repro.models.recsys import FMConfig

ARCH_ID = "fm"
FAMILY = "recsys"


def config() -> FMConfig:
    return FMConfig(name=ARCH_ID, n_sparse=39, embed_dim=10)


def reduced_config() -> FMConfig:
    return FMConfig(
        name=ARCH_ID + "-reduced", n_sparse=5, embed_dim=4,
        vocab_sizes=(50, 60, 70, 80, 90),
    )
