"""autoint: attention-based feature interactions, 39 sparse fields,
embed_dim=16, 3 attention layers, 2 heads, d_attn=32.  [arXiv:1810.11921]"""
from repro.models.recsys import AutoIntConfig

ARCH_ID = "autoint"
FAMILY = "recsys"


def config() -> AutoIntConfig:
    return AutoIntConfig(
        name=ARCH_ID, n_sparse=39, embed_dim=16, n_attn_layers=3,
        n_heads=2, d_attn=32,
    )


def reduced_config() -> AutoIntConfig:
    return AutoIntConfig(
        name=ARCH_ID + "-reduced", n_sparse=5, embed_dim=8,
        n_attn_layers=2, n_heads=2, d_attn=8,
        vocab_sizes=(50, 60, 70, 80, 90),
    )
