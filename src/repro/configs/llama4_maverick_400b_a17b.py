"""llama4-maverick-400b-a17b: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, 3:1 local:global.
Optimizer moments run in bf16 for this arch (f32 would not fit per-device
HBM even fully ZeRO-sharded on one pod; DESIGN.md Section 5).
[hf:meta-llama/Llama-4-Maverick-17B-128E]
"""
from repro.models.transformer import LMConfig, MoEConfig

ARCH_ID = "llama4-maverick-400b-a17b"
FAMILY = "lm"
OPT_MOMENT_DTYPE = "bfloat16"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048,
        moe=MoEConfig(n_experts=128, top_k=1, shared_expert=True),
        period=4, local_positions=(0, 1, 2), local_chunk=8192,
    )


def reduced_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=1, shared_expert=True),
        period=4, local_positions=(0, 1, 2), local_chunk=32,
        param_dtype=jnp.float32, act_dtype=jnp.float32,
    )
