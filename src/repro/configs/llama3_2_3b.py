"""llama3.2-3b: dense 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-3B]"""
from repro.models.transformer import LMConfig

ARCH_ID = "llama3.2-3b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256,
    )


def reduced_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512,
        param_dtype=jnp.float32, act_dtype=jnp.float32,
    )
