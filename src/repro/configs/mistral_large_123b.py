"""mistral-large-123b: dense 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.models.transformer import LMConfig

ARCH_ID = "mistral-large-123b"
FAMILY = "lm"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=28672, vocab=32768,
    )


def reduced_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512,
        param_dtype=jnp.float32, act_dtype=jnp.float32,
    )
