"""dlrm-mlperf: MLPerf DLRM benchmark config (Criteo 1TB): 13 dense +
26 sparse (official per-table row counts, ~188M rows total), embed_dim=128,
bot MLP 13-512-256-128, dot interaction, top MLP 1024-1024-512-256-1.
[arXiv:1906.00091]"""
from repro.models.recsys import DLRMConfig

ARCH_ID = "dlrm-mlperf"
FAMILY = "recsys"


def config() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID)


def reduced_config() -> DLRMConfig:
    return DLRMConfig(
        name=ARCH_ID + "-reduced", n_dense=13, n_sparse=4, embed_dim=16,
        bot_mlp=(32, 16), top_mlp=(32, 16, 1),
        vocab_sizes=(100, 200, 300, 400),
    )
