"""sasrec: self-attentive sequential recommendation, embed_dim=50,
2 blocks, 1 head, seq_len=50; 1M-item catalog (retrieval_cand scores the
full catalog).  [arXiv:1808.09781]"""
from repro.models.recsys import SASRecConfig

ARCH_ID = "sasrec"
FAMILY = "recsys"


def config() -> SASRecConfig:
    return SASRecConfig(
        name=ARCH_ID, n_items=1_000_000, embed_dim=50, n_blocks=2,
        n_heads=1, seq_len=50,
    )


def reduced_config() -> SASRecConfig:
    return SASRecConfig(
        name=ARCH_ID + "-reduced", n_items=200, embed_dim=8, n_blocks=2,
        n_heads=1, seq_len=10,
    )
