"""nequip: 5 interaction layers, 32 channels, l_max=2, n_rbf=8, cutoff=5 A,
E(3)-equivariant tensor products (Cartesian form — DESIGN.md Section 2).
[arXiv:2101.03164]"""
from repro.models.nequip import NequIPConfig

ARCH_ID = "nequip"
FAMILY = "gnn"


def config(d_feat_in: int = 1433) -> NequIPConfig:
    return NequIPConfig(
        name=ARCH_ID, n_layers=5, channels=32, l_max=2, n_rbf=8, cutoff=5.0,
        d_feat_in=d_feat_in,
    )


def reduced_config() -> NequIPConfig:
    return NequIPConfig(
        name=ARCH_ID + "-reduced", n_layers=2, channels=8, l_max=2, n_rbf=4,
        cutoff=5.0, d_feat_in=16,
    )
