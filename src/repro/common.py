"""Common utilities: pytree dataclasses, dtype policy, small helpers.

Every index structure in ``repro`` is an immutable dataclass registered as a
JAX pytree.  Array fields are pytree leaves (so structures can be passed
through ``jit``/``vmap`` unchanged); integer metadata that must be *static*
(used in shapes, loop bounds, branch decisions at trace time) is declared in
``meta`` and becomes part of the pytree treedef, i.e. a hashable aux value.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

#: Default integer dtype for index structures.  All supported collection
#: sizes fit in int32 (n < 2^31); construction paths that could overflow use
#: int64 transiently on the host.
IDX = jnp.int32

#: Word width for plain bitvectors.  32-bit words keep popcount cheap on the
#: VPU and keep gathers aligned.
WORD_BITS = 32


def pytree_dataclass(cls=None, *, meta: Sequence[str] = ()):
    """Register a frozen dataclass as a JAX pytree.

    ``meta`` fields are static (hashable, part of the treedef); all other
    fields are array leaves.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        field_names = [f.name for f in dataclasses.fields(c)]
        data_fields = [f for f in field_names if f not in meta]
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=list(meta)
        )
        return c

    return wrap(cls) if cls is not None else wrap


def replace(obj, **kwargs):
    """dataclasses.replace that works through the pytree registration."""
    return dataclasses.replace(obj, **kwargs)


# ---------------------------------------------------------------------------
# Small math helpers (host-side, used at build time)
# ---------------------------------------------------------------------------

def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def ceil_log2(x: int) -> int:
    """ceil(lg x) for x >= 1; 0 for x <= 1."""
    if x <= 1:
        return 0
    return int(x - 1).bit_length()


def floor_log2(x: int) -> int:
    if x < 1:
        raise ValueError("floor_log2 requires x >= 1")
    return int(x).bit_length() - 1


def round_up(x: int, m: int) -> int:
    return ceil_div(x, m) * m


def delta_code_len(v: int) -> int:
    """Length in bits of the Elias delta code of v >= 1.

    Used only for *modeled* compressed-size accounting (the paper's space
    axis); the working representation is word-aligned.
    """
    if v < 1:
        raise ValueError("delta codes encode positive integers")
    n = floor_log2(v)          # v = 2^n + rest
    nn = floor_log2(n + 1)
    return 2 * nn + 1 + n


def gamma_code_len(v: int) -> int:
    if v < 1:
        raise ValueError("gamma codes encode positive integers")
    return 2 * floor_log2(v) + 1


def elias_fano_bits(m: int, n: int) -> int:
    """Modeled size in bits of an Elias-Fano / sparse bitmap with m ones out
    of n positions (Okanohara & Sadakane 2007): m*ceil(lg(n/m)) + 2m."""
    if m == 0:
        return 0
    low = max(0, ceil_log2(max(1, n // m)))
    return m * low + 2 * m


# ---------------------------------------------------------------------------
# Array helpers
# ---------------------------------------------------------------------------

def as_i32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=IDX)


def np_as_i32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int32)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Population count of each element (works on any integer dtype)."""
    return jax.lax.population_count(x)


def device_nbytes(tree: Any) -> int:
    """Total bytes of all array leaves in a pytree (the *working set*)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif isinstance(leaf, (int, float, bool)):
            total += 8
    return total


def tree_map_with_doc(fn: Callable, tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree)


def bits_per_char(bits: float, n: int) -> float:
    """Space accounting in the paper's unit (bits per collection symbol)."""
    return bits / max(1, n)
