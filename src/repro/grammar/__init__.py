"""Grammar compression: Re-Pair (Larsson & Moffat 2000) for sequences and
document sets — the compressor behind PDL (Section 4) and the Grammar
baseline (Claude & Munro 2013)."""

from repro.grammar.repair import (
    Grammar,
    repair_compress,
    repair_compress_lists,
    repair_expand_host,
    modeled_bits_grammar,
)

__all__ = [
    "Grammar",
    "repair_compress",
    "repair_compress_lists",
    "repair_expand_host",
    "modeled_bits_grammar",
]
