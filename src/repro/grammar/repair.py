"""Re-Pair grammar compression (Larsson & Moffat 2000).

Used by PDL (Section 4) to compress precomputed document lists: frequent
pairs of symbols are replaced by fresh nonterminals until no pair repeats.
On repetitive collections the document sets of nearby suffix-tree nodes are
near-identical, so a handful of rules covers most of the data — this is the
mechanism behind PDL's space wins in Figures 6-9.

Implementation notes (host-side build, offline — as in the paper):

* *Batched rounds*: instead of replacing one pair per round, each round
  replaces a maximal set of top-frequency pairs whose symbol sets are
  disjoint (so occurrences cannot chain across different chosen pairs).
  Overlaps within a single pair (the "aaa" case) are resolved leftmost-
  greedily with a vectorized run-parity trick.  This keeps the build
  O(rounds * n) with rounds ~ lg-ish in practice, numpy-vectorized.

* Lists are compressed *jointly* (shared grammar) by concatenating them
  with separator symbols that are excluded from pairing — the paper's PDL
  also shares its grammar across all stored sets.

* Decompression is available host-side (tests, build) and as a bounded
  jitted stack expansion in repro.core.pdl (query path).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.common import ceil_log2


@dataclasses.dataclass(frozen=True)
class Grammar:
    """rules[r] = (left, right); nonterminal r encodes symbol alphabet+r.

    seq: the compressed sequence (int64, may contain nonterminals)
    alphabet: first nonterminal id == alphabet
    """

    seq: np.ndarray
    rules: np.ndarray  # int64[nrules, 2]
    alphabet: int

    @property
    def nrules(self) -> int:
        return int(self.rules.shape[0])

    def is_terminal(self, sym) -> bool:
        return sym < self.alphabet

    def expansion_lengths(self) -> np.ndarray:
        """Length of the terminal expansion of every nonterminal."""
        lens = np.zeros(self.nrules, dtype=np.int64)
        for r in range(self.nrules):  # rules reference only older rules
            l, rr = self.rules[r]
            ll = 1 if l < self.alphabet else lens[l - self.alphabet]
            rl = 1 if rr < self.alphabet else lens[rr - self.alphabet]
            lens[r] = ll + rl
        return lens


def _replace_round(seq: np.ndarray, pairs: np.ndarray, first_new: int):
    """Replace every chosen pair (pairs[i] -> symbol first_new + i) in one
    vectorized pass.  Chosen pairs have pairwise-disjoint symbol sets."""
    n = len(seq)
    if n < 2:
        return seq
    key = seq[:-1].astype(np.int64) * (1 << 32) + seq[1:].astype(np.int64)
    pkeys = pairs[:, 0].astype(np.int64) * (1 << 32) + pairs[:, 1].astype(np.int64)
    order = np.argsort(pkeys)
    sorted_keys = pkeys[order]
    idx = np.searchsorted(sorted_keys, key)
    idx_c = np.minimum(idx, len(sorted_keys) - 1)
    hit = sorted_keys[idx_c] == key
    pair_id = np.where(hit, order[idx_c], -1)

    cand = pair_id >= 0
    # leftmost-greedy within runs of consecutive candidates (same pair only,
    # e.g. "aaa" with pair (a,a)); distinct chosen pairs cannot chain.
    pos = np.arange(n - 1)
    run_start = cand & ~np.concatenate([[False], cand[:-1]])
    start_idx = np.maximum.accumulate(np.where(run_start, pos, -1))
    parity_ok = ((pos - start_idx) % 2) == 0
    valid = cand & parity_ok

    out_vals = seq.copy()
    out_vals[np.flatnonzero(valid)] = first_new + pair_id[valid]
    keep = np.ones(n, dtype=bool)
    keep[np.flatnonzero(valid) + 1] = False
    return out_vals[keep]


def repair_compress(
    seq,
    alphabet: int,
    min_freq: int = 2,
    max_rules: int | None = None,
    batch: int = 64,
    separator: int | None = None,
) -> Grammar:
    """Compress ``seq`` (symbols in [0, alphabet)) with Re-Pair.

    separator: symbol excluded from all pairs (list boundaries).
    batch: max number of disjoint pairs replaced per round.
    """
    seq = np.asarray(seq, dtype=np.int64)
    rules: list[tuple[int, int]] = []
    next_sym = alphabet
    while True:
        if max_rules is not None and len(rules) >= max_rules:
            break
        n = len(seq)
        if n < 2:
            break
        key = seq[:-1] * (1 << 32) + seq[1:]
        if separator is not None:
            ok = (seq[:-1] != separator) & (seq[1:] != separator)
            key = key[ok]
        if len(key) == 0:
            break
        uniq, counts = np.unique(key, return_counts=True)
        hot = counts >= min_freq
        if not hot.any():
            break
        uniq, counts = uniq[hot], counts[hot]
        by_count = np.argsort(-counts)
        chosen = []
        used: set[int] = set()
        for j in by_count:
            a = int(uniq[j] >> 32)
            b = int(uniq[j] & 0xFFFFFFFF)
            if a in used or b in used:
                continue
            chosen.append((a, b))
            used.add(a)
            used.add(b)
            if len(chosen) >= batch:
                break
            if max_rules is not None and len(rules) + len(chosen) >= max_rules:
                break
        if not chosen:
            break
        pairs = np.asarray(chosen, dtype=np.int64)
        seq = _replace_round(seq, pairs, next_sym)
        rules.extend(chosen)
        next_sym += len(chosen)
    rules_arr = (
        np.asarray(rules, dtype=np.int64)
        if rules
        else np.zeros((0, 2), dtype=np.int64)
    )
    return Grammar(seq=seq, rules=rules_arr, alphabet=alphabet)


def repair_compress_lists(lists, alphabet: int, **kwargs):
    """Compress many lists with a shared grammar.

    Returns (Grammar over the concatenation-with-separators, list offsets
    into the compressed sequence).  The separator symbol is ``alphabet``;
    rule nonterminals start at ``alphabet + 1``.
    """
    sep = alphabet
    parts = []
    for lst in lists:
        parts.append(np.asarray(lst, dtype=np.int64))
        parts.append(np.asarray([sep], dtype=np.int64))
    cat = np.concatenate(parts) if parts else np.zeros(0, np.int64)
    g = repair_compress(cat, alphabet + 1, separator=sep, **kwargs)
    # split compressed sequence back into per-list segments
    seq = g.seq
    bounds = np.flatnonzero(seq == sep)
    starts = np.concatenate([[0], bounds[:-1] + 1]) if len(bounds) else np.zeros(0, np.int64)
    segments = [seq[s:e] for s, e in zip(starts, bounds)]
    return g, segments


def repair_expand_host(g: Grammar, seq) -> np.ndarray:
    """Expand a (sub)sequence of terminals/nonterminals to terminals."""
    out: list[int] = []
    stack: list[int] = list(np.asarray(seq, dtype=np.int64))[::-1]
    while stack:
        s = stack.pop()
        if s < g.alphabet:
            out.append(int(s))
        else:
            l, r = g.rules[int(s) - g.alphabet]
            stack.append(int(r))
            stack.append(int(l))
    return np.asarray(out, dtype=np.int64)


def modeled_bits_grammar(g: Grammar, d_plus: int | None = None) -> int:
    """Paper accounting: |A| lg(d + n_R) for the sequence array plus
    |G| lg d for the rules, plus the two delimiting bitvectors (Sec 4.1)."""
    width_seq = ceil_log2(g.alphabet + g.nrules + 1)
    width_rule = ceil_log2(max(2, g.alphabet))
    seq_bits = len(g.seq) * width_seq
    rule_bits = 2 * g.nrules * width_rule
    bitvecs = len(g.seq) + 2 * g.nrules + 64
    return int(seq_bits + rule_bits + bitvecs)
